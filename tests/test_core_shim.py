"""The deprecated ``repro.core`` import path — covered on purpose.

The unit suites migrated to ``repro.cpm.reference`` (PR 4), so the legacy
shim would otherwise keep working (or silently break) by accident.  This
test pins the contract: importing ``repro.core`` warns ``DeprecationWarning``
once, re-exports the very same function objects the new path serves, and the
subpackage aliases (``repro.core.movable`` etc.) resolve to the new modules.

Run in a subprocess so a ``repro.core`` import cached by another test file
cannot swallow the import-time warning.
"""

import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import warnings

with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    import repro.core as core

assert any(issubclass(w.category, DeprecationWarning) for w in caught), \
    "repro.core import must warn DeprecationWarning"
assert any("repro.cpm" in str(w.message) for w in caught), \
    "the warning must point at the replacement path"

import repro.cpm.reference as ref
from repro.cpm import collectives

# the shim re-exports the SAME objects, not parallel copies
assert core.substring_match is ref.searchable.substring_match
assert core.activation_mask is ref.pe_array.activation_mask
assert core.shift_range is ref.movable.shift_range
assert core.histogram is ref.comparable.histogram
assert core.section_sum is ref.computable.section_sum
assert core.ring_allreduce is collectives.ring_allreduce
assert core.movable is ref.movable
assert core.collectives is collectives

# and every name promised in __all__ resolves
for name in core.__all__:
    assert getattr(core, name, None) is not None, name
print("SHIM_OK")
"""


def test_legacy_core_shim_warns_and_aliases():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=REPO_ROOT, env=env, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHIM_OK" in r.stdout
