"""Differential tests: scan engine vs step-by-step reference oracle.

Every rewrite of the serving stack must be token-identical to the
preserved step-by-step path (`repro.serve.reference.ReferenceEngine`),
across batch sizes, prompt lengths, draft lengths, and architectures
(pure-attention and hybrid recurrent).  Also probes that batched
speculative decoding verifies a full draft in exactly ONE `lm` forward
call per round, and that acceptance stats clip the final overshooting
round.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.serve import Engine, GenConfig, ReferenceEngine

CFG = all_configs()["granite-8b"].smoke()
HYB = all_configs()["recurrentgemma-9b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return (Engine(CFG, params, max_len=128),
            ReferenceEngine(CFG, params, max_len=128))


@pytest.fixture(scope="module")
def hybrid():
    params = lm.init_params(HYB, jax.random.PRNGKey(0))
    return (Engine(HYB, params, max_len=96),
            ReferenceEngine(HYB, params, max_len=96))


def _prompt(b, s, cfg, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)


def _repetitive(b, s):
    """Prompts with period-6 structure so n-gram lookup finds drafts."""
    period = jnp.arange(6, dtype=jnp.int32) + 7
    return jnp.tile(period[None], (b, -(-s // 6)))[:, :s]


# -- greedy scan engine == reference, batch in {1, 4} ----------------------

@pytest.mark.parametrize("b,s,new", [(1, 16, 12), (4, 16, 12), (4, 8, 6)])
def test_scan_matches_reference_greedy(granite, b, s, new):
    eng, ref = granite
    toks = _prompt(b, s, CFG)
    out, stats = eng.generate({"tokens": toks}, GenConfig(max_new_tokens=new))
    rout, _ = ref.generate({"tokens": toks}, GenConfig(max_new_tokens=new))
    assert out.shape == (b, s + new)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    assert stats["emitted"] == b * new


def test_scan_matches_reference_hybrid(hybrid):
    """Hybrid arch (rglru + local-window ring), decoding past the window."""
    eng, ref = hybrid
    toks = _prompt(2, 20, HYB)
    gen = GenConfig(max_new_tokens=24)          # window=16 => ring wraps
    out, _ = eng.generate({"tokens": toks}, gen)
    rout, _ = ref.generate({"tokens": toks}, gen)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


def test_scan_matches_reference_sampled(granite):
    """The scan program replicates the reference's per-token rng splits, so
    sampled generation is identical too, not just greedy."""
    eng, ref = granite
    toks = _prompt(2, 12, CFG)
    gen = GenConfig(max_new_tokens=10, temperature=0.8, top_k=8)
    out, _ = eng.generate({"tokens": toks}, gen, rng=jax.random.PRNGKey(7))
    rout, _ = ref.generate({"tokens": toks}, gen, rng=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


# -- batched speculative decoding == greedy --------------------------------

@pytest.mark.parametrize("b,draft_len", [(1, 4), (4, 4), (4, 6)])
def test_spec_batched_matches_greedy(granite, b, draft_len):
    eng, _ = granite
    toks = _repetitive(b, 18)
    base, _ = eng.generate({"tokens": toks}, GenConfig(max_new_tokens=14))
    spec, stats = eng.generate({"tokens": toks},
                               GenConfig(max_new_tokens=14,
                                         ngram_spec=draft_len))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))
    assert stats["rounds"] > 0
    assert stats["emitted"] == b * 14


def test_spec_random_prompts_match_greedy(granite):
    """Rows with no n-gram hit fall back to degenerate drafts but still
    emit the model token — output must stay identical."""
    eng, _ = granite
    toks = _prompt(4, 16, CFG, seed=3)
    base, _ = eng.generate({"tokens": toks}, GenConfig(max_new_tokens=12))
    spec, _ = eng.generate({"tokens": toks},
                           GenConfig(max_new_tokens=12, ngram_spec=4))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))


def test_spec_hybrid_matches_greedy(hybrid):
    """Speculative rollback of recurrent (rglru) state and the local-window
    ring: per-row snapshot selection, not just KV length truncation."""
    eng, _ = hybrid
    toks = _repetitive(2, 24)
    base, _ = eng.generate({"tokens": toks}, GenConfig(max_new_tokens=20))
    spec, _ = eng.generate({"tokens": toks},
                           GenConfig(max_new_tokens=20, ngram_spec=4))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))


def test_spec_enc_dec_matches_greedy():
    """Enc-dec arch: cross-attention KV must survive speculative rollback
    (its length is the encoder sequence, never a decoder position)."""
    cfg = all_configs()["seamless-m4t-large-v2"].smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=96)
    batch = {"tokens": _repetitive(2, 18),
             "src_embeds": jax.random.normal(jax.random.PRNGKey(2),
                                             (2, 10, cfg.d_model))}
    base, _ = eng.generate(batch, GenConfig(max_new_tokens=12))
    spec, stats = eng.generate(batch, GenConfig(max_new_tokens=12,
                                                ngram_spec=4))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))
    assert stats["rounds"] > 0


def test_spec_requires_cache_slack(granite):
    """Without draft slack past the token budget, the final verify round
    would wrap the slot write onto live prompt KV — must be rejected."""
    eng, _ = granite
    toks = _repetitive(2, 18)
    small = Engine(CFG, eng.params, max_len=18 + 6)
    with pytest.raises(ValueError, match="max_len"):
        small.generate({"tokens": toks},
                       GenConfig(max_new_tokens=6, ngram_spec=4))


def test_spec_matches_reference_spec_b1(granite):
    """At batch 1 the batched spec engine and the reference spec round must
    produce the same tokens (both reduce to greedy output)."""
    eng, ref = granite
    toks = _repetitive(1, 18)
    gen = GenConfig(max_new_tokens=12, ngram_spec=4)
    out, _ = eng.generate({"tokens": toks}, gen)
    rout, _ = ref.generate({"tokens": toks}, gen)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


# -- call-count probe: one lm forward per draft ----------------------------

def test_spec_verifies_draft_in_one_forward_call(granite):
    eng, _ = granite
    draft_len = 4
    calls = []
    inner = eng._decode_multi

    def probe(params, tokens, caches, pos):
        calls.append(int(tokens.shape[1]))
        return inner(params, tokens=tokens, caches=caches, pos=pos)

    eng._decode_multi = probe
    try:
        toks = _repetitive(3, 18)
        _, stats = eng.generate({"tokens": toks},
                                GenConfig(max_new_tokens=14,
                                          ngram_spec=draft_len))
    finally:
        eng._decode_multi = inner
    # exactly one multi-token forward per speculative round, each covering
    # the full draft — never one call per draft token
    assert len(calls) == stats["rounds"] > 0
    assert all(c == draft_len for c in calls)


# -- acceptance-stats accounting -------------------------------------------

def test_spec_overshoot_stats_are_clipped(granite):
    """A final round may verify more draft tokens than the remaining
    budget; accepted/emitted must count only tokens actually returned."""
    eng, _ = granite
    b, new = 3, 7                     # 7 % draft_len != 0 => overshoot
    toks = _repetitive(b, 18)
    out, stats = eng.generate({"tokens": toks},
                              GenConfig(max_new_tokens=new, ngram_spec=5))
    assert out.shape == (b, 18 + new)
    assert stats["emitted"] == b * new
    assert 0 <= stats["accepted"] <= stats["proposed"]
    assert 0.0 <= stats["acceptance_rate"] <= 1.0
    # accepted tokens are a subset of emitted ones (first token + per-round
    # correction tokens are emitted but not "accepted")
    assert stats["accepted"] <= stats["emitted"]


def test_zero_token_budget_returns_prompt(granite):
    eng, ref = granite
    toks = _prompt(2, 8, CFG)
    out, stats = eng.generate({"tokens": toks}, GenConfig(max_new_tokens=0))
    rout, _ = ref.generate({"tokens": toks}, GenConfig(max_new_tokens=0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    assert stats["emitted"] == 0


def test_scan_stats_shape(granite):
    eng, _ = granite
    out, stats = eng.generate({"tokens": _prompt(2, 8, CFG)},
                              GenConfig(max_new_tokens=4))
    assert stats == {"accepted": 0, "proposed": 0, "rounds": 0,
                     "emitted": 8, "acceptance_rate": 0.0}
