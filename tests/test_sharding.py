"""Partition-rule unit tests (no multi-device needed: specs are pure)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


class FakeMesh:
    """Just enough of a Mesh for spec building."""
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}
    size = 512


CTX = sh.ShardingCtx(mesh=FakeMesh(), data_axes=("pod", "data"),
                     model_axis="model", fsdp=True)
CTX1 = sh.ShardingCtx(mesh=FakeMesh(), data_axes=("pod", "data"),
                      model_axis="model", fsdp=False)


def test_attention_weights():
    assert sh.param_spec("/blocks/attn/wq", (8192, 8192), CTX) == \
        P(("pod", "data"), "model")
    assert sh.param_spec("/blocks/attn/wo", (8192, 8192), CTX) == \
        P("model", ("pod", "data"))


def test_stacked_leading_axis_never_sharded():
    s = sh.param_spec("/blocks/attn/wq", (80, 8192, 8192), CTX)
    assert s == P(None, ("pod", "data"), "model")


def test_divisibility_fallback():
    # 49155 vocab does not divide 16 -> falls off the vocab-sharded spec
    s = sh.param_spec("/emb", (49155, 1024), CTX)
    assert s[0] is None
    # padded vocab shards cleanly
    s = sh.param_spec("/emb", (49664, 1024), CTX)
    assert s == P("model", ("pod", "data"))


def test_experts_sharded_over_model():
    s = sh.param_spec("/blocks/ffn/expert_in", (32, 1024, 512), CTX)
    assert s[0] == "model"


def test_small_dims_replicate():
    # sLSTM recurrent weights: 4 heads can't shard over 16
    s = sh.param_spec("/blocks/slstm/rec_w", (4, 512, 2048), CTX)
    assert s == P(None, None, None)


def test_fsdp_off_drops_dp():
    s = sh.param_spec("/blocks/attn/wq", (8192, 8192), CTX1)
    assert s == P(None, "model")


def test_compute_spec_strips_dp_axes():
    s = sh.compute_spec("/blocks/attn/wq", (8192, 8192), CTX)
    assert s == P(None, "model")
    s = sh.compute_spec("/blocks/attn/wo", (8192, 8192), CTX)
    assert s == P("model", None)


def test_act_spec_divisibility():
    # 40 heads don't divide 16 -> head axis replicates
    s = sh.act_spec("bhsd", (32, 40, 4096, 128), CTX)
    assert s[1] is None
    s = sh.act_spec("bhsd", (32, 64, 4096, 128), CTX)
    assert s[1] == "model"


def test_norm_scale_replicated_when_indivisible():
    s = sh.param_spec("/blocks/norm1/scale", (5120,), CTX)
    assert s == P("model")          # 5120 % 16 == 0
    s = sh.param_spec("/blocks/norm1/scale", (1023,), CTX)
    assert s == P(None)
