"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cpm_kernels, flash_attention as fa, ops, ref


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kvh,s,d", [
        (1, 4, 4, 128, 64),    # MHA
        (2, 8, 2, 256, 64),    # GQA 4:1
        (1, 4, 1, 128, 128),   # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_naive(self, b, h, kvh, s, d, dtype):
        q = rand(0, (b, h, s, d), dtype)
        k = rand(1, (b, kvh, s, d), dtype)
        v = rand(2, (b, kvh, s, d), dtype)
        got = fa.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        want = ref.attention_naive(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL[dtype], rtol=TOL[dtype])

    def test_non_causal(self):
        q = rand(0, (1, 2, 128, 64), jnp.float32)
        k = rand(1, (1, 2, 128, 64), jnp.float32)
        v = rand(2, (1, 2, 128, 64), jnp.float32)
        got = fa.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
        want = ref.attention_naive(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("window", [32, 128])
    def test_local_window(self, window):
        q = rand(0, (1, 2, 256, 64), jnp.float32)
        k = rand(1, (1, 2, 256, 64), jnp.float32)
        v = rand(2, (1, 2, 256, 64), jnp.float32)
        got = fa.flash_attention(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64)
        want = ref.attention_naive(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("block_k", [64, 128, 512])
    def test_chunked_ref_matches_naive(self, block_k):
        q = rand(3, (2, 4, 512, 64), jnp.float32)
        k = rand(4, (2, 2, 512, 64), jnp.float32)
        v = rand(5, (2, 2, 512, 64), jnp.float32)
        got = ref.flash_attention_ref(q, k, v, causal=True, block_k=block_k)
        want = ref.attention_naive(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_chunked_ref_grad_finite(self):
        q = rand(6, (1, 2, 128, 32), jnp.float32)
        k = rand(7, (1, 2, 128, 32), jnp.float32)
        v = rand(8, (1, 2, 128, 32), jnp.float32)
        g = jax.grad(lambda q: ref.flash_attention_ref(q, k, v).sum())(q)
        assert np.isfinite(np.asarray(g)).all()

    def test_decode_matches_last_row(self):
        s = 128
        q = rand(9, (2, 4, 1, 64), jnp.float32)
        k = rand(10, (2, 2, s, 64), jnp.float32)
        v = rand(11, (2, 2, s, 64), jnp.float32)
        got = ref.decode_attention_ref(q, k, v, cache_len=s)
        want = ref.attention_naive(q, k, v, causal=True)  # sq=1 aligned at end
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_decode_cache_mask(self):
        q = rand(9, (1, 2, 1, 32), jnp.float32)
        k = rand(10, (1, 2, 64, 32), jnp.float32)
        v = rand(11, (1, 2, 64, 32), jnp.float32)
        short = ref.decode_attention_ref(q, k[:, :, :40], v[:, :, :40], cache_len=40)
        padded = ref.decode_attention_ref(q, k, v, cache_len=40)
        np.testing.assert_allclose(np.asarray(short), np.asarray(padded), atol=2e-5)


class TestCPMKernels:
    @pytest.mark.parametrize("r,n", [(1, 8), (4, 64), (2, 130)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
    def test_oddeven_sort(self, r, n, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(r * n), (r, n)) * 100).astype(dtype)
        got = cpm_kernels.oddeven_sort(x)
        np.testing.assert_array_equal(np.asarray(got), np.sort(np.asarray(x), -1))

    @pytest.mark.parametrize("n,section", [(64, 16), (1000, 32), (4096, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_section_sum(self, n, section, dtype):
        x = rand(n, (n,), dtype)
        got = float(cpm_kernels.section_sum(x, section))
        want = float(np.asarray(x, np.float32).sum())
        np.testing.assert_allclose(got, want, rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-2)

    @pytest.mark.parametrize("n,m", [(64, 4), (256, 16)])
    def test_template_match(self, n, m):
        data = jax.random.normal(jax.random.PRNGKey(0), (3, n))
        t = jax.random.normal(jax.random.PRNGKey(1), (m,))
        got = cpm_kernels.template_match(data, t)
        want = jax.vmap(lambda d: ref.template_match_ref(d, t))(data)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_template_match_finds_plant(self):
        data = jnp.full((1, 128), 9.0).at[0, 40:44].set(jnp.array([1.0, 2, 3, 4]))
        t = jnp.array([1.0, 2, 3, 4])
        sad = np.asarray(cpm_kernels.template_match(data, t))[0]
        assert sad.argmin() == 40 and sad[40] == 0

    @pytest.mark.parametrize("n,m", [(32, 2), (128, 5)])
    def test_substring_match(self, n, m):
        hay = jax.random.randint(jax.random.PRNGKey(2), (2, n), 0, 4)
        nee = jax.random.randint(jax.random.PRNGKey(3), (m,), 0, 4)
        got = np.asarray(cpm_kernels.substring_match(hay, nee)).astype(bool)
        want = np.asarray(jax.vmap(lambda h: ref.substring_match_ref(h, nee))(hay))
        np.testing.assert_array_equal(got, want)

    def test_compare_histogram_promote_float_datum(self):
        """Raw kernels promote mixed dtypes like the reference oracle —
        a fractional threshold on int rows must not truncate."""
        x = jnp.array([[0, 1, 2, 3]], jnp.int32)
        got = cpm_kernels.compare(x, 2.5, "lt")
        np.testing.assert_array_equal(np.asarray(got),
                                      [[True, True, True, False]])
        h = cpm_kernels.histogram(jnp.array([0, 1, 2, 3], jnp.int32),
                                  jnp.array([0.0, 1.5, 4.0]))
        np.testing.assert_array_equal(np.asarray(h), [2, 2])

    @pytest.mark.parametrize("taps", [(1.0, 2.0, 1.0), (1.0, 1.0, 1.0, 1.0, 1.0)])
    def test_stencil(self, taps):
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 64))
        got = cpm_kernels.stencil(x, taps)
        want = jax.vmap(lambda r: ref.stencil_ref(r, list(taps)))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


class TestOpsDispatch:
    def test_ops_sort_modes_agree(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32))
        np.testing.assert_allclose(np.asarray(ops.sort(x, impl="ref")),
                                   np.asarray(ops.sort(x, impl="interpret")))

    def test_ops_attention_modes_agree(self):
        q = rand(0, (1, 2, 128, 32), jnp.float32)
        k = rand(1, (1, 1, 128, 32), jnp.float32)
        v = rand(2, (1, 1, 128, 32), jnp.float32)
        a = ops.attention(q, k, v, impl="ref")
        b = ops.attention(q, k, v, impl="interpret", block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
