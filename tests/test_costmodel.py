"""Cost-aware scheduling, the autotuned pallas layer, and the PR-6
regression fixes (auto interpret, section validation, shape-only operand
introspection)."""

import inspect
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cpm import CPMProgram, cpm_array, tuning
from repro.cpm.backends import get_backend
from repro.cpm.program import (CostParams, count_pallas_calls, group_cost,
                               instruction_steps, roofline_params, run_plan,
                               schedule)
from repro.cpm.program import costmodel
from repro.cpm.program.ir import Instruction
from repro.kernels import cpm_kernels as K


def _pipeline(n):
    return (CPMProgram()
            .append("shift", start=0, end=n // 2, shift=1, fill=0)
            .append("insert", pos=5, values=jnp.arange(3, dtype=jnp.int32))
            .append("compare", datum=3, op="lt")
            .append("activate", start=0, end=n - 1, carry=2)
            .append("stencil", taps=(1.0, 2.0, 1.0), wrap=False))


#: launch-dominated machine: fusing always pays (the TPU-shaped regime)
FUSE_PARAMS = CostParams(1e-5, 1e-12, 1e-5, 1e-12, source="override")
#: launch-free machine with a pricier fused byte slope: never fuse
EAGER_PARAMS = CostParams(1e-9, 1e-12, 1e-9, 2e-12, source="override")


@pytest.fixture
def isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CPM_TUNING_CACHE",
                       str(tmp_path / "tuning.json"))
    tuning.clear(in_process_only=False)
    yield tmp_path / "tuning.json"
    tuning.clear(in_process_only=False)


# ---------------------------------------------------------------------------
# the three PR-6 regression fixes
# ---------------------------------------------------------------------------

class TestRegressions:
    def test_recorded_section_zero_raises(self):
        # `operands.get("section") or section` used to silently replace a
        # recorded 0 with the caller default
        instr = Instruction("section_sum", {"section": 0})
        with pytest.raises(ValueError, match="section must be >= 1"):
            instruction_steps(instr, 64)

    def test_recorded_section_zero_beats_caller_default(self):
        instr = Instruction("section_sum", {"section": 0})
        with pytest.raises(ValueError, match="section must be >= 1"):
            instruction_steps(instr, 64, section=8)

    def test_instr_m_reads_shapes_without_materializing(self):
        # a ShapeDtypeStruct has a .shape but cannot be jnp.asarray'd —
        # schedule-time introspection must not force materialization
        spec = jax.ShapeDtypeStruct((5,), jnp.int32)
        instr = Instruction("substring_match", {"needle": spec,
                                                "where": "end"})
        assert instruction_steps(instr, 64) == 5

    def test_instr_m_plain_lists_still_work(self):
        instr = Instruction("substring_match", {"needle": [1, 2, 3],
                                                "where": "end"})
        assert instruction_steps(instr, 64) == 3
        hist = Instruction("histogram", {"edges": np.arange(5.0)})
        assert instruction_steps(hist, 64) == 5  # m=4 bins + count step

    def test_kernel_interpret_defaults_are_auto(self):
        # every public kernel: interpret: bool | None = None (auto),
        # matching CPMArray — not a hardcoded interpreter default
        kernels = [K.activate, K.shift_range, K.oddeven_sort, K.section_sum,
                   K.compare, K.histogram, K.section_limit, K.super_sum,
                   K.super_limit, K.template_match, K.substring_match,
                   K.stencil, K.compact, K.gather_rows, K.scatter_rows,
                   K.fused_stream]
        for fn in kernels:
            sig = inspect.signature(fn)
            assert sig.parameters["interpret"].default is None, fn

    def test_resolve_interpret_rule(self):
        on_tpu = jax.default_backend() == "tpu"
        assert K.resolve_interpret(None) is (not on_tpu)
        assert K.resolve_interpret(True) is True
        assert K.resolve_interpret(False) is False

    def test_kernel_runs_with_auto_interpret(self):
        out = K.activate(64, 3, 10, 2)
        assert out.shape == (64,) and out.dtype == bool


# ---------------------------------------------------------------------------
# the cost model
# ---------------------------------------------------------------------------

class TestCostAwareSchedule:
    def test_bare_schedule_keeps_fuse_all(self):
        plan = schedule(_pipeline(256))
        assert [g.kind for g in plan.groups] == ["fused"]
        assert plan.groups[0].decision is None

    def test_launch_bound_params_fuse(self):
        dev = cpm_array(jnp.zeros(256, jnp.int32), 256, backend="pallas",
                        interpret=True)
        plan = schedule(_pipeline(256), device=dev, cost=FUSE_PARAMS)
        assert [g.kind for g in plan.groups] == ["fused"]
        assert plan.groups[0].decision["fuse"] is True

    def test_byte_bound_params_fall_back_to_eager(self):
        dev = cpm_array(jnp.zeros(256, jnp.int32), 256, backend="pallas",
                        interpret=True)
        plan = schedule(_pipeline(256), device=dev, cost=EAGER_PARAMS)
        assert [g.kind for g in plan.groups] == ["eager"]
        d = plan.groups[0].decision
        assert d["fuse"] is False and d["eager_us"] < d["fused_us"]

    def test_reference_backend_skips_cost_decisions(self):
        dev = cpm_array(jnp.zeros(256, jnp.int32), 256)
        plan = schedule(_pipeline(256), device=dev, cost=EAGER_PARAMS)
        assert [g.kind for g in plan.groups] == ["fused"]

    def test_eager_group_dispatches_per_op(self):
        n = 256
        data = jnp.asarray(np.random.default_rng(0).integers(0, 9, n),
                           jnp.int32)
        dev = cpm_array(data, n, backend="pallas", interpret=True)
        plan = schedule(_pipeline(n), device=dev, cost=EAGER_PARAMS)

        def run(d):
            arr = cpm_array(d, n, backend="pallas", interpret=True)
            return run_plan(plan, arr, backend="pallas",
                            interpret=True)[0].data

        assert count_pallas_calls(run, data) == len(plan.program)

    def test_eager_group_bit_identical_to_fused(self):
        n = 256
        data = jnp.asarray(np.random.default_rng(1).integers(0, 9, n),
                           jnp.int32)
        dev = cpm_array(data, n, backend="pallas", interpret=True)
        fused = schedule(_pipeline(n), device=dev, cost=FUSE_PARAMS)
        eager = schedule(_pipeline(n), device=dev, cost=EAGER_PARAMS)
        of, pf = run_plan(fused, dev, backend="pallas", interpret=True)
        oe, pe = run_plan(eager, dev, backend="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(of.data),
                                      np.asarray(oe.data))
        for a, b in zip(pf, pe):
            if a is not None:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_steps_report_surfaces_decisions(self):
        dev = cpm_array(jnp.zeros(256, jnp.int32), 256, backend="pallas",
                        interpret=True)
        plan = schedule(_pipeline(256), device=dev, cost=EAGER_PARAMS)
        rep = plan.steps_report(256)
        assert rep["total"] == plan.predicted_steps(256)
        (entry,) = rep["schedule"]
        assert entry["kind"] == "eager"
        assert entry["decision"]["params"] == "override"
        assert "eager" in plan.describe()

    def test_truncate_cost_metadata_is_free(self):
        # truncate moves only the length register: 0 passes, 0 launches —
        # distinct from its 1 concurrent step
        t = Instruction("truncate", {"new_len": 3})
        fused_s, eager_s = group_cost([t], 1, 1024, 4, EAGER_PARAMS)
        assert eager_s == 0.0
        assert fused_s == EAGER_PARAMS.fused_launch_s

    def test_roofline_priors_fuse_multi_op_runs(self):
        params = roofline_params()
        prog = _pipeline(4096)
        fused_s, eager_s = group_cost(list(prog.instructions), 1, 4096, 4,
                                      params)
        assert fused_s < eager_s  # launches dominate at HBM byte rates

    def test_calibration_spills_and_reloads(self, isolated_cache):
        params = costmodel.params_for(True)
        assert params.source in ("calibrated", "roofline")
        if params.source == "calibrated":
            spilled = json.loads(isolated_cache.read_text())
            key = f"calib:{tuning.backend_key(True)}"
            assert spilled[key]["source"] == "calibrated"
            # second call answers from the cache (no re-measurement)
            again = costmodel.params_for(True)
            assert again == params

    def test_calibrate_disabled_falls_back_to_roofline(self, isolated_cache,
                                                       monkeypatch):
        monkeypatch.setenv("REPRO_CPM_CALIBRATE", "0")
        assert costmodel.params_for(True).source == "roofline"


# ---------------------------------------------------------------------------
# the autotuned pallas layer
# ---------------------------------------------------------------------------

class TestAutotune:
    def test_pick_caches_and_spills(self, isolated_cache):
        calls = []

        def run(c):
            calls.append(c)
            return jnp.zeros(4) + c

        first = tuning.pick("t:unit", [1, 2, 3], run, default=1, reps=1)
        assert first in (1, 2, 3)
        n_calls = len(calls)
        again = tuning.pick("t:unit", [1, 2, 3], run, default=1, reps=1)
        assert again == first and len(calls) == n_calls  # cache hit
        assert json.loads(isolated_cache.read_text())["t:unit"] == first

    def test_autotune_disabled_returns_default(self, isolated_cache,
                                               monkeypatch):
        monkeypatch.setenv("REPRO_CPM_AUTOTUNE", "0")
        got = tuning.pick("t:off", [1, 2], lambda c: jnp.zeros(2),
                          default=7)
        assert got == 7
        assert not isolated_cache.exists()

    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=1, max_value=7),
           st.integers(min_value=1, max_value=9))
    def test_fused_stream_block_r_bit_identical(self, r, block_r):
        n = 128
        rng = np.random.default_rng(r)
        x = jnp.asarray(rng.integers(0, 9, (r, n)), jnp.int32)
        ul = jnp.asarray(rng.integers(4, n, (r,)), jnp.int32)
        descs = (
            ("shift", (("shift", 1), ("has_fill", True)), 2),
            ("compare", (("op", "lt"), ("has_mask", False),
                         ("ct", "int32")), 1),
            ("insert", (("k", 2),), 2),
            ("truncate", (), 1),
        )
        operands = (
            jnp.asarray([[0, 64]], jnp.int32),
            jnp.asarray([[7]], jnp.int32),
            jnp.asarray([[4]], jnp.int32),
            jnp.asarray(rng.integers(0, 4, (r, 1)), jnp.int32),
            jnp.asarray(rng.integers(0, 9, (r, 2)), jnp.int32),
            jnp.asarray(rng.integers(2, n, (r, 1)), jnp.int32),
        )
        ref = K.fused_stream(x, ul, descs, operands, block_r=1,
                             interpret=True)
        got = K.fused_stream(x, ul, descs, operands, block_r=block_r,
                             interpret=True)
        assert got[0].shape == ref[0].shape          # shape-stable
        np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
        np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))
        for a, b in zip(ref[2], got[2]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=4, deadline=None)
    @given(st.integers(min_value=1, max_value=3),
           st.integers(min_value=2048, max_value=6000))
    def test_tuned_sections_bit_identical_to_untuned(self, r, n):
        # the autotuned section choice may regroup the reduction but the
        # result must be shape-stable and (for ints) bit-identical
        # (in-process cache only: tuning may store decisions under these
        # synthetic shapes, which is fine — results cannot depend on them)
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.integers(-50, 50, (r, n)), jnp.int32)
        backend = get_backend("pallas", interpret=True)
        tuned = backend.section_sum(x)               # section=None -> tune
        untuned = K.section_sum(x, 97, interpret=True)
        assert tuned.shape == untuned.shape == (r,)
        np.testing.assert_array_equal(np.asarray(tuned),
                                      np.asarray(untuned))
        tl = backend.super_limit(x, mode="max")
        np.testing.assert_array_equal(
            np.asarray(tl), np.asarray(K.super_limit(x, 64, interpret=True)))

    def test_measurement_skipped_under_trace(self, isolated_cache):
        # under an active trace, timing would measure tracing and stage
        # every probe dispatch into the caller's jaxpr (and an ambient
        # ensure_compile_time_eval breaks pallas kernel tracing outright)
        # — so the cache layer must refuse to measure: pick() returns the
        # default uncached, and params_for falls back to roofline
        assert tuning.measurable()
        seen = []

        def traced(x):
            seen.append(tuning.measurable())
            got = tuning.pick("t:traced", [1, 2],
                              lambda c: jnp.zeros(4), default=9)
            seen.append(got)
            seen.append(costmodel.params_for(True).source)
            return x + 1

        jax.make_jaxpr(traced)(jnp.zeros(4, jnp.int32))
        assert seen == [False, 9, "roofline"]
        assert not isolated_cache.exists()           # nothing was cached
        # ...but a decision made eagerly beforehand is visible in-trace
        # (fresh input shape: identical avals would hit the trace cache
        # and skip the body entirely)
        tuning.store("t:traced", 2)
        jax.make_jaxpr(traced)(jnp.zeros(5, jnp.int32))
        assert seen[4] == 2                          # cache hit under trace

    def test_executor_block_r_threshold(self):
        # tiny streams skip tuning entirely (static default 1)
        from repro.cpm.program import executors
        descs = (("compare", (("op", "eq"), ("has_mask", False),
                              ("ct", "int32")), 1),)
        backend = get_backend("pallas", interpret=True)
        got = executors._fused_block_r(
            descs, (jnp.zeros((1, 1), jnp.int32),),
            jnp.zeros((2, 64), jnp.int32), jnp.zeros(2, jnp.int32),
            2, 64, backend)
        assert got == 1


# ---------------------------------------------------------------------------
# PR-7 satellite: measured pallas crossover consulted by backend="auto"
# ---------------------------------------------------------------------------

class TestPallasMinN:
    """`auto_backend_name` thresholds on the *measured* reference/pallas
    crossover when the tuning cache has one (written by the `cpm_ops`
    benchmark sweep), per-op first, then the pooled `*` entry, static
    PALLAS_MIN_N as the last resort."""

    def test_static_fallback_when_unmeasured(self, isolated_cache):
        from repro.cpm.backends import PALLAS_MIN_N, pallas_min_n
        assert pallas_min_n("compare") == PALLAS_MIN_N
        assert pallas_min_n() == PALLAS_MIN_N

    def test_per_op_beats_pooled_beats_static(self, isolated_cache):
        from repro.cpm.backends import (PALLAS_MIN_N, auto_backend_name,
                                        pallas_min_n)
        bk = tuning.backend_key(False)
        tuning.store(f"xover:*:{bk}", 2048)
        assert pallas_min_n("compare") == 2048       # pooled entry
        tuning.store(f"xover:compare:{bk}", 512)
        assert pallas_min_n("compare") == 512        # per-op wins
        assert pallas_min_n("section_sum") == 2048   # others still pooled
        assert pallas_min_n() == 2048
        tuning.clear()
        assert pallas_min_n("compare") == PALLAS_MIN_N

    def test_cpu_resolve_unaffected_by_cache(self, isolated_cache):
        """On CPU, auto routes to reference regardless of any crossover
        entry (residency check comes first)."""
        from repro.cpm.backends import auto_backend_name
        bk = tuning.backend_key(False)
        tuning.store(f"xover:*:{bk}", 1)             # pallas "always wins"
        data = jnp.zeros((4096,), jnp.int32)
        assert auto_backend_name(data, "compare") == "reference"
        got = cpm_array(data, 4096, backend="auto").compare(0)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.ones((4096,), np.int32))
