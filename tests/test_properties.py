"""Hypothesis property tests on system invariants (routing, sampling,
cache management, collectives algebra)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.cpm.reference import comparable, computable, movable, searchable
from repro.serve import sampling


class TestRoutingInvariants:
    @given(st.integers(0, 10), st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_moe_routing_exact_k_and_gates(self, seed, k):
        """Every token routes to exactly k distinct experts; kept gates are
        a normalized sub-distribution."""
        from repro.configs import MoEConfig, get_config
        import dataclasses
        from repro.models import layers as L

        cfg = dataclasses.replace(
            get_config("granite-moe-1b-a400m").smoke(),
            moe=MoEConfig(n_experts=8, top_k=k, capacity_factor=4.0))
        key = jax.random.PRNGKey(seed)
        p = L.init_moe(cfg, key)
        x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.bfloat16) * 0.1
        y, aux = L.apply_moe(p, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y, np.float32)).all()
        assert float(aux) >= 0
        # routing mask invariant
        probs = jax.nn.softmax(
            x.reshape(-1, cfg.d_model).astype(jnp.float32) @ p["router"])
        mask = comparable.topk_mask(probs, k)
        assert np.all(np.asarray(mask.sum(-1)) == k)

    @given(st.integers(0, 20), st.integers(1, 6))
    @settings(max_examples=40, deadline=None)
    def test_topk_mask_threshold_semantics(self, seed, k):
        x = jax.random.normal(jax.random.PRNGKey(seed), (5, 12))
        m = np.asarray(comparable.topk_mask(x, k))
        xv = np.asarray(x)
        for row in range(5):
            kept = np.sort(xv[row][m[row]])
            dropped = xv[row][~m[row]]
            assert len(kept) == k
            if len(dropped):
                assert kept[0] >= dropped.max() - 1e-6


class TestSamplingInvariants:
    @given(st.integers(0, 10), st.floats(0.1, 0.99))
    @settings(max_examples=30, deadline=None)
    def test_top_p_mass_at_least_p(self, seed, p):
        probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed), (3, 32)))
        m = sampling.top_p_mask(probs, p)
        mass = np.asarray(jnp.where(m, probs, 0).sum(-1))
        assert np.all(mass >= p - 0.02)
        # masks are downward-closed in probability
        pv = np.asarray(probs)
        mv = np.asarray(m)
        for r in range(3):
            thr = pv[r][mv[r]].min()
            assert not np.any(pv[r][~mv[r]] > thr + 1e-7)


class TestCacheInvariants:
    @given(st.lists(st.booleans(), min_size=4, max_size=16))
    @settings(max_examples=30, deadline=None)
    def test_compact_preserves_kept_order(self, keep):
        from repro.serve import kv_cache
        s = len(keep)
        k = jnp.arange(1 * 1 * s * 2, dtype=jnp.float32).reshape(1, 1, s, 2)
        keep_arr = jnp.asarray(keep)[None]
        ks, vs, ln = kv_cache.compact_slots(k, k, keep_arr)
        n = int(ln[0])
        assert n == sum(keep)
        want = np.asarray(k)[0, 0][np.asarray(keep)]
        np.testing.assert_array_equal(np.asarray(ks)[0, 0, :n], want)


class TestMovableInvariants:
    """§4 content-movable semantics at range boundaries (PR-2 satellite)."""

    @given(st.lists(st.integers(-5, 5), min_size=1, max_size=8),
           st.integers(0, 24), st.integers(1, 20))
    @settings(max_examples=40, deadline=None)
    def test_insert_delete_roundtrip(self, vals, pos, used):
        """delete(insert(x, p, v), p, len(v)) restores the used prefix."""
        n = 32
        k = len(vals)
        used = min(used, n - k)
        pos = min(pos, used)
        x = jnp.asarray((np.arange(n) * 7 + 3) % 23, jnp.int32)
        v = jnp.asarray(vals, jnp.int32)
        y = movable.insert(x, pos, v, used)
        # the inserted window must actually be present before deleting
        np.testing.assert_array_equal(np.asarray(y)[pos: pos + k], vals)
        z = movable.delete(y, pos, k, used + k)
        np.testing.assert_array_equal(np.asarray(z)[:used],
                                      np.asarray(x)[:used])

    @given(st.integers(0, 31), st.integers(0, 31), st.integers(1, 5))
    @settings(max_examples=40, deadline=None)
    def test_shift_right_fill_and_drop(self, a, b, s):
        """shift>0: [start+s, end+s]∩[0,n) receives, overflow past the
        physical end is dropped, vacated low slots take the fill."""
        n = 32
        start, end = min(a, b), max(a, b)
        x = np.arange(n) + 1
        out = np.asarray(movable.shift_range(jnp.asarray(x), start, end, s,
                                             fill=-7))
        want = x.copy()
        for i in range(n):
            if start + s <= i <= min(end + s, n - 1):
                want[i] = x[i - s]                   # moved content
            elif start <= i <= min(end, start + s - 1):
                want[i] = -7                         # vacated, filled
        np.testing.assert_array_equal(out, want)

    @given(st.integers(0, 31), st.integers(0, 31), st.integers(1, 5))
    @settings(max_examples=40, deadline=None)
    def test_shift_left_fill_and_drop(self, a, b, s):
        """shift<0: content crossing address 0 is dropped, vacated high
        slots of the range take the fill."""
        n = 32
        start, end = min(a, b), max(a, b)
        x = np.arange(n) + 1
        out = np.asarray(movable.shift_range(jnp.asarray(x), start, end, -s,
                                             fill=-7))
        want = x.copy()
        for i in range(n):
            if max(start - s, 0) <= i <= end - s:
                want[i] = x[i + s]
            elif max(start, end - s + 1) <= i <= end:
                want[i] = -7
        np.testing.assert_array_equal(out, want)


class TestAlgebraInvariants:
    @given(st.lists(st.integers(-3, 3), min_size=1, max_size=5),
           st.lists(st.integers(-3, 3), min_size=1, max_size=5))
    @settings(max_examples=30, deadline=None)
    def test_stencil_compose_commutes(self, a, b):
        """Eq. 7-7: A # B == B # A."""
        np.testing.assert_array_equal(computable.compose_taps(a, b),
                                      computable.compose_taps(b, a))

    @given(st.lists(st.integers(-3, 3), min_size=1, max_size=4),
           st.lists(st.integers(-3, 3), min_size=1, max_size=4),
           st.lists(st.integers(-3, 3), min_size=1, max_size=4))
    @settings(max_examples=25, deadline=None)
    def test_stencil_compose_associates(self, a, b, c):
        """Eq. 7-8: (A # B) # C == A # (B # C)."""
        np.testing.assert_array_equal(
            computable.compose_taps(computable.compose_taps(a, b), c),
            computable.compose_taps(a, computable.compose_taps(b, c)))

    @given(st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                    min_size=2, max_size=40),
           st.integers(1, 39))
    @settings(max_examples=30, deadline=None)
    def test_shift_then_unshift_identity(self, vals, start):
        x = jnp.asarray(vals, jnp.float32)
        n = x.shape[0]
        start = min(start, n - 1)
        end = n - 2
        if start > end:
            return
        y = movable.shift_range(x, start, end, 1)
        z = movable.shift_range(y, start + 1, end + 1, -1)
        np.testing.assert_allclose(np.asarray(z)[start + 1: end + 1],
                                   np.asarray(x)[start + 1: end + 1])

    @given(st.lists(st.integers(0, 3), min_size=2, max_size=30))
    @settings(max_examples=30, deadline=None)
    def test_spec_verify_prefix_property(self, toks):
        """verify_draft returns the exact longest common prefix length."""
        draft = jnp.asarray(toks, jnp.int32)
        target = jnp.asarray(toks, jnp.int32)
        assert int(searchable.verify_draft(draft, target)) == len(toks)
        if len(toks) > 1:
            t2 = np.array(toks)
            t2[len(toks) // 2] = (t2[len(toks) // 2] + 1) % 4
            got = int(searchable.verify_draft(draft, jnp.asarray(t2)))
            assert got == len(toks) // 2
