"""Roofline HLO-parser unit tests against hand-written HLO snippets."""

import numpy as np
import pytest

from repro.analysis import roofline

HLO = """\
HloModule jit_step

%region_body (p: (s32[], f32[4,256])) -> (s32[], f32[4,256]) {
  %ar = f32[4,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %t = (s32[], f32[4,256]) tuple(%i, %ar)
}

%region_cond (p: (s32[], f32[4,256])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[4,256], b: bf16[8,128]) -> f32[4,256] {
  %ag = bf16[8,2048]{1,0} all-gather(bf16[8,128]{1,0} %b), replica_groups=[16,16]<=[256], dimensions={1}
  %w = (s32[], f32[4,256]) while(%init), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"10"}}
  %cp = f32[4,256]{1,0} collective-permute(f32[4,256]{1,0} %a), source_target_pairs={{0,1}}
  ROOT %r = f32[4,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_with_trip_counts():
    st = roofline.parse_hlo(HLO, 256)
    # all-gather: out 8*2048*2 bytes * 15/16
    ag = 8 * 2048 * 2 * 15 / 16
    # all-reduce inside while x10: 2 * in_bytes * 15/16
    ar = 10 * 2 * (4 * 256 * 4) * 15 / 16
    cp = 4 * 256 * 4
    np.testing.assert_allclose(st.by_kind["all-gather"], ag)
    np.testing.assert_allclose(st.by_kind["all-reduce"], ar)
    np.testing.assert_allclose(st.by_kind["collective-permute"], cp)
    np.testing.assert_allclose(st.per_chip_bytes, ag + ar + cp)
    assert st.op_counts["all-reduce"] == 10


def test_shape_bytes_tuple():
    assert roofline._shape_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2
    assert roofline._shape_bytes("s32[] constant") == 4  # scalar = one element


def test_group_size_formats():
    assert roofline._group_size("replica_groups=[16,16]<=[256]", 1) == 16
    assert roofline._group_size("replica_groups={{0,1,2,3}}", 1) == 4
    assert roofline._group_size("no groups here", 7) == 7


def test_roofline_terms_bound_selection():
    t = roofline.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert t["bound"] == "memory"
    assert t["step_s_lower_bound"] == pytest.approx(2.0)


def test_model_flops():
    from repro.configs import SHAPES, get_config
    cfg = get_config("granite-8b")
    mf = roofline.model_flops(cfg, SHAPES["train_4k"])
    assert mf == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    # moe uses active params
    moe = get_config("phi3.5-moe-42b-a6.6b")
    mf2 = roofline.model_flops(moe, SHAPES["prefill_32k"])
    assert mf2 == pytest.approx(2 * moe.active_param_count() * 32 * 32768, rel=1e-6)
