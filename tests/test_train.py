"""End-to-end training: loss decreases, checkpoint/kill/restore resumes
bit-exactly, optimizer math, fault-tolerance loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.train import (OptConfig, checkpoint, data, fault_tolerance as ft,
                         init_opt_state, make_train_step)

CFG = all_configs()["granite-8b"].smoke()


def make_state(seed=0):
    params = lm.init_params(CFG, jax.random.PRNGKey(seed))
    return {"params": params, "opt": init_opt_state(params)}


@pytest.fixture(scope="module")
def trained():
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(CFG, opt_cfg, num_microbatches=2,
                                   remat=True, loss_chunk=16))
    pipe = data.make_pipeline(CFG, type("S", (), {"seq_len": 32, "global_batch": 8})())
    state = make_state()
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state["params"], state["opt"], m = step(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases(trained):
    losses = trained
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"
    assert all(np.isfinite(losses))


def test_microbatch_equivalence():
    """Gradient accumulation over k microbatches == single big batch."""
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1 = jax.jit(make_train_step(CFG, opt_cfg, num_microbatches=1, loss_chunk=16))
    s2 = jax.jit(make_train_step(CFG, opt_cfg, num_microbatches=4, loss_chunk=16))
    state_a, state_b = make_state(1), make_state(1)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (8, 32), 0,
                                          CFG.vocab_size)}
    pa, _, ma = s1(state_a["params"], state_a["opt"], batch)
    pb, _, mb = s2(state_b["params"], state_b["opt"], batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=2e-3)
    la, lb = jax.tree.leaves(pa), jax.tree.leaves(pb)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3, rtol=2e-2)


def test_checkpoint_roundtrip(tmp_path):
    state = make_state(3)
    pipe = data.make_pipeline(CFG, type("S", (), {"seq_len": 32, "global_batch": 4})())
    next(pipe)
    t = checkpoint.save(str(tmp_path), 7, state, extra={"data": pipe.state()},
                        async_=True)
    t.join()
    assert checkpoint.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extra = checkpoint.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 1


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir (simulated crash mid-write) is never picked up."""
    state = {"x": jnp.ones((4,))}
    checkpoint.save(str(tmp_path), 1, state)
    os.makedirs(tmp_path / "step_00000002.tmp", exist_ok=True)
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_fault_tolerant_restart_identical(tmp_path):
    """Train 6 steps straight vs 3 steps + kill + restore + 3 steps: the
    final params must match bit-for-bit (data pipeline state included)."""
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=20)
    step = jax.jit(make_train_step(CFG, opt_cfg, num_microbatches=1, loss_chunk=16))

    def step_fn(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, m = step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    shape = type("S", (), {"seq_len": 32, "global_batch": 4})()

    # run A: 6 straight steps
    pipe = data.make_pipeline(CFG, shape)
    state = make_state(5)
    for _ in range(6):
        state, _ = step_fn(state, next(pipe))
    ref = jax.tree.leaves(state["params"])

    # run B: 3 steps, checkpoint, "crash", restore, 3 more
    fcfg = ft.FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=3)
    pipe = data.make_pipeline(CFG, shape)
    state = make_state(5)
    state, hb = ft.run_loop(fcfg, state, step_fn, pipe, 0, 3)
    del state                                     # crash
    state2, extra, start = ft.resume_or_init(fcfg, lambda: make_state(5))
    pipe2 = data.make_pipeline(CFG, shape)
    pipe2.restore(extra["data"])
    assert start == 3
    state2, _ = ft.run_loop(fcfg, state2, step_fn, pipe2, start, 6)
    got = jax.tree.leaves(state2["params"])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optimizer_adamw_math():
    from repro.train import optimizer as opt
    params = {"w": jnp.ones((2, 2)), "norm": {"scale": jnp.ones((2,))}}
    grads = jax.tree.map(jnp.ones_like, params)
    state = opt.init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=10, weight_decay=0.0,
                    clip_norm=100.0)
    p2, s2, m = opt.apply_updates(params, grads, state, cfg)
    # first step: update = g/sqrt(g^2) = 1 -> p -= lr (cosine factor at step 1)
    lr1 = float(opt.schedule(cfg, jnp.asarray(1)))
    np.testing.assert_allclose(np.asarray(p2["w"]), 1 - lr1, rtol=1e-4)
    assert int(s2["step"]) == 1


def test_grad_clip():
    from repro.train import optimizer as opt
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(opt.global_norm(clipped)), 1.0, rtol=1e-5)
