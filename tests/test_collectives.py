"""Distributed CPM collectives — run in a subprocess with 8 host devices so
the main test process keeps the default single-device view."""

import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")   # never probe TPU backends
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.cpm import collectives

mesh = jax.make_mesh((2, 4), ("pod", "data"))
x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)

# ring all-reduce (R7-faithful) == psum
f = shard_map(lambda v: collectives.ring_allreduce(v, "data"),
              mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))
got = f(x)
want = np.tile(np.asarray(x).reshape(2, 4, 4).sum(1, keepdims=True), (1, 4, 1)).reshape(8, 4)
# careful: in_specs shards rows over "data" only -> each data rank holds 2 rows;
# ring_allreduce sums across the 4 data ranks (pod axis unsharded -> replicated rows)
x2 = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
mesh1 = jax.make_mesh((4,), ("data",))
f1 = shard_map(lambda v: collectives.ring_allreduce(v, "data"),
               mesh=mesh1, in_specs=jax.sharding.PartitionSpec("data", None),
               out_specs=jax.sharding.PartitionSpec("data", None))
got1 = np.asarray(f1(x2))
want1 = np.tile(np.asarray(x2).sum(0, keepdims=True), (4, 1))
np.testing.assert_allclose(got1, want1)
print("ring_allreduce OK")

# tree (super-connectivity) all-reduce == psum
f2 = shard_map(lambda v: collectives.tree_allreduce(v, "data"),
               mesh=mesh1, in_specs=jax.sharding.PartitionSpec("data", None),
               out_specs=jax.sharding.PartitionSpec("data", None))
np.testing.assert_allclose(np.asarray(f2(x2)), want1)
print("tree_allreduce OK")

# hierarchical two-phase psum across pod x data == full sum
P_ = jax.sharding.PartitionSpec
f3 = shard_map(lambda v: collectives.hierarchical_psum(v, "data", "pod", mode="two_phase"),
               mesh=mesh, in_specs=P_(("pod", "data"), None), out_specs=P_(("pod", "data"), None))
got3 = np.asarray(f3(x))
want3 = np.tile(np.asarray(x).sum(0, keepdims=True), (8, 1))
np.testing.assert_allclose(got3, want3)
print("hierarchical_psum OK")

# ring mode as well
f4 = shard_map(lambda v: collectives.hierarchical_psum(v, "data", "pod", mode="ring"),
               mesh=mesh, in_specs=P_(("pod", "data"), None), out_specs=P_(("pod", "data"), None))
np.testing.assert_allclose(np.asarray(f4(x)), want3)
print("hierarchical ring OK")

# distributed sectioned sum (the paper's sqrt-N sum with chips as sections)
v = jnp.arange(64, dtype=jnp.float32)
f5 = shard_map(lambda s: collectives.distributed_section_sum(s, "data")[None],
               mesh=mesh1, in_specs=P_("data"), out_specs=P_("data"))
np.testing.assert_allclose(np.asarray(f5(v)), np.full(4, 2016.0))
print("distributed_section_sum OK")

# ring_shift moves the shard to the neighbor
f6 = shard_map(lambda s: collectives.ring_shift(s, "data", 1),
               mesh=mesh1, in_specs=P_("data"), out_specs=P_("data"))
got6 = np.asarray(f6(jnp.arange(8, dtype=jnp.float32)))
np.testing.assert_allclose(got6, np.roll(np.arange(8, dtype=np.float32), 2))
print("ring_shift OK")

# grad_sync over a pytree
tree = {"a": jnp.ones((8, 2)), "b": jnp.full((8,), 2.0)}
f7 = shard_map(lambda t: collectives.grad_sync(t, ("pod", "data")),
               mesh=mesh, in_specs=P_(("pod", "data")), out_specs=P_(("pod", "data")))
out = f7(tree)
np.testing.assert_allclose(np.asarray(out["a"]), np.full((8, 2), 8.0))
print("grad_sync OK")
print("ALL_OK")
"""


@pytest.mark.slow
def test_collectives_8dev():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd=REPO_ROOT)
    assert "ALL_OK" in r.stdout, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
