"""`repro.serve.gateway` — admission batching, preemption, async front door.

The gateway's contracts:

  * **preemption identity** — a session that is parked to host memory and
    re-admitted emits byte-identical greedy tokens to a solo
    ``Engine.generate`` run (the KV/token pages round-trip losslessly);
  * **batched admission** — same-length waiting prompts share ONE prefill
    launch (counter-asserted), and the plan preserves FIFO arrival order
    within and across buckets;
  * **preemption policy** — the LRU victim honors the min-resident /
    min-remaining / max-parks guards and only evicts for fresh arrivals;
  * **front-door faces** — sync submit/tick/result/cancel and async
    asubmit/stream/aresult/serve deliver the same tokens, per-request
    sampling params apply per pool row, and SLO grading runs in virtual
    decode-step time;
  * **traffic traces** — seeded generators replay byte-identically.
"""

import asyncio
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.serve import Engine, GenConfig, Gateway
from repro.serve.gateway import admission
from repro.serve.gateway.preempt import PreemptConfig, Preemptor

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))
import traffic  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


def _prompt(seed, s):
    return jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                              CFG.vocab_size)


def _solo(engine, prompt, budget):
    out, _ = engine.generate({"tokens": prompt[None]},
                             GenConfig(max_new_tokens=budget))
    return np.asarray(out[0])


# ---------------------------------------------------------------------------
# preemption identity
# ---------------------------------------------------------------------------

class TestPreemptionIdentity:
    def test_parked_and_readmitted_matches_solo(self, granite):
        """Manually park the pool's LRU session mid-decode; after the
        restore the drained tokens equal the undisturbed solo run."""
        pool = granite.session_pool(slots=2, n_banks=1)
        prompts = [_prompt(i, 8) for i in range(2)]
        sids = [pool.submit(p, 10) for p in prompts]
        for _ in range(3):
            pool.step()
        victim = pool.victim_session()
        assert victim is not None
        pool.park(victim.sid)
        assert pool.stats()["parked"] == 1
        outs = pool.drain()
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 10))
        st = pool.stats()
        assert st["preemptions"] == 1 and st["restores"] == 1

    def test_gateway_burst_preempts_with_identity(self, granite):
        """Incumbents squat every slot; a burst of short requests forces
        LRU parking.  Everyone — preempted incumbents included — matches
        solo greedy."""
        gw = Gateway(granite, slots=2, chunk=1,
                     preempt=PreemptConfig(min_resident=1, min_remaining=1,
                                           max_parks=3))
        incumbents = [gw.submit(_prompt(i, 8), 12) for i in range(2)]
        for _ in range(3):
            gw.tick()
        burst = [gw.submit(_prompt(10 + i, 6), 3) for i in range(2)]
        for rid in burst + incumbents:
            toks = gw.result(rid)
            req = gw.request(rid)
            np.testing.assert_array_equal(
                toks, _solo(granite, req.prompt, req.budget))
        assert gw.stats()["preemptions"] > 0
        assert any(gw.request(r).parks > 0 for r in incumbents)

    def test_multiple_parks_still_identical(self, granite):
        """A session parked more than once still round-trips losslessly."""
        pool = granite.session_pool(slots=2, n_banks=1)
        p = _prompt(42, 8)
        sid = pool.submit(p, 12)
        other = pool.submit(_prompt(43, 8), 12)
        for parks in range(2):
            for _ in range(2):
                pool.step()
            pool.park(sid)
            pool.step()                  # restore happens on admit
        outs = pool.drain()
        np.testing.assert_array_equal(outs[sid], _solo(granite, p, 12))
        np.testing.assert_array_equal(outs[other],
                                      _solo(granite, _prompt(43, 8), 12))


# ---------------------------------------------------------------------------
# batched admission
# ---------------------------------------------------------------------------

class _FakeSession:
    def __init__(self, sid, prompt_len, phase="waiting", parked=None):
        self.sid = sid
        self.prompt_len = prompt_len
        self.phase = phase
        self.parked = parked            # real Session always has this field


class TestAdmissionPlan:
    def test_buckets_by_length_preserving_fifo(self):
        ss = [_FakeSession(0, 8), _FakeSession(1, 6), _FakeSession(2, 8),
              _FakeSession(3, 6)]
        plan = admission.plan(ss)
        assert [[s.sid for s in b] for b in plan.buckets] == [[0, 2], [1, 3]]
        assert plan.launches == 2
        assert plan.sessions == 4

    def test_parked_split_into_restore_group(self):
        ss = [_FakeSession(0, 8), _FakeSession(1, 8, phase="parked"),
              _FakeSession(2, 8)]
        plan = admission.plan(ss)
        assert [s.sid for s in plan.restores[0]] == [1]
        assert [[s.sid for s in b] for b in plan.buckets] == [[0, 2]]

    def test_no_batching_is_strict_fifo_singletons(self):
        ss = [_FakeSession(0, 8), _FakeSession(1, 6), _FakeSession(2, 8)]
        plan = admission.plan(ss, batching=False)
        assert [[s.sid for s in b] for b in plan.buckets] == [[0], [1], [2]]
        assert plan.launches == 3

    def test_pool_counts_one_prefill_per_bucket(self, granite):
        """4 same-length submissions into 4 slots: ONE prefill launch,
        one admit batch — and outputs still match solo."""
        pool = granite.session_pool(slots=4, n_banks=1)
        prompts = [_prompt(20 + i, 8) for i in range(4)]
        sids = [pool.submit(p, 4) for p in prompts]
        outs = pool.drain()
        st = pool.stats()
        assert st["prefill_launches"] == 1
        assert st["admit_batches"] == 1
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 4))

    def test_unbatched_pool_counts_one_prefill_each(self, granite):
        pool = granite.session_pool(slots=4, n_banks=1,
                                    admit_batching=False)
        prompts = [_prompt(30 + i, 8) for i in range(4)]
        sids = [pool.submit(p, 3) for p in prompts]
        outs = pool.drain()
        assert pool.stats()["prefill_launches"] == 4
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 3))

    def test_mixed_lengths_one_launch_per_length(self, granite):
        pool = granite.session_pool(slots=4, n_banks=1)
        prompts = [_prompt(40, 8), _prompt(41, 12), _prompt(42, 8),
                   _prompt(43, 12)]
        sids = [pool.submit(p, 3) for p in prompts]
        outs = pool.drain()
        assert pool.stats()["prefill_launches"] == 2
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 3))


# ---------------------------------------------------------------------------
# preemption policy guards
# ---------------------------------------------------------------------------

class TestPreemptorPolicy:
    def test_no_waiting_no_preemption(self, granite):
        pool = granite.session_pool(slots=2)
        for i in range(2):
            pool.submit(_prompt(50 + i, 8), 8)
        pool.step()
        pre = Preemptor(pool, PreemptConfig(min_resident=1))
        assert pre.maybe_preempt() == 0
        assert pre.preempted == 0

    def test_min_resident_floor_holds(self, granite):
        """With min_resident == slots, arrivals can never evict."""
        pool = granite.session_pool(slots=2)
        for i in range(2):
            pool.submit(_prompt(60 + i, 8), 8)
        pool.step()
        pool.submit(_prompt(62, 8), 2)          # fresh arrival, queue full
        pre = Preemptor(pool, PreemptConfig(min_resident=2))
        assert pre.maybe_preempt() == 0
        assert pre.denied > 0

    def test_near_finished_sessions_protected(self, granite):
        """min_remaining protects sessions about to finish anyway."""
        pool = granite.session_pool(slots=2)
        sids = [pool.submit(_prompt(70 + i, 8), 3) for i in range(2)]
        for _ in range(2):
            pool.step()                          # 3 emitted, 0 remaining soon
        pool.submit(_prompt(72, 8), 2)
        pre = Preemptor(pool, PreemptConfig(min_resident=1,
                                            min_remaining=2))
        assert pre.maybe_preempt() == 0

    def test_max_parks_caps_thrash(self, granite):
        pool = granite.session_pool(slots=1)
        sid = pool.submit(_prompt(80, 8), 16)
        pool.step()
        sess = pool.table.get(sid)
        sess.parks = 3
        pool.submit(_prompt(81, 8), 2)
        pre = Preemptor(pool, PreemptConfig(min_resident=0, min_remaining=1,
                                            max_parks=3))
        assert pre.maybe_preempt() == 0


# ---------------------------------------------------------------------------
# front door: sync + async faces, sampling, validation, SLO
# ---------------------------------------------------------------------------

class TestGatewayFaces:
    def test_sync_submit_result_matches_solo(self, granite):
        gw = Gateway(granite, slots=2)
        p = _prompt(90, 8)
        rid = gw.submit(p, 5)
        np.testing.assert_array_equal(gw.result(rid), _solo(granite, p, 5))
        req = gw.request(rid)
        assert req.done and req.latency_steps >= 0
        assert req.ttft_steps >= 0

    def test_cancel_returns_prefix(self, granite):
        gw = Gateway(granite, slots=2)
        p = _prompt(91, 8)
        rid = gw.submit(p, 10)
        gw.tick()
        gw.tick()
        toks = gw.cancel(rid)
        want = _solo(granite, p, 10)
        assert 8 < len(toks) <= len(want)
        np.testing.assert_array_equal(toks, want[:len(toks)])
        assert gw.request(rid).cancelled

    def test_validation_surfaces_at_submit(self, granite):
        gw = Gateway(granite, slots=2)
        with pytest.raises(ValueError, match="empty prompt"):
            gw.submit(np.zeros((0,), np.int32), 4)
        with pytest.raises(ValueError, match="must be positive"):
            gw.submit(_prompt(92, 8), 0)
        assert gw.stats()["requests"] == 0    # nothing half-registered

    def test_per_request_sampling_rides_next_to_greedy(self, granite):
        """A sampled request in the same pool batch must not perturb its
        greedy neighbors."""
        gw = Gateway(granite, slots=2, rng=jax.random.PRNGKey(7))
        pg = _prompt(93, 8)
        rid_greedy = gw.submit(pg, 6)
        rid_sampled = gw.submit(
            _prompt(94, 8), 6,
            gen=GenConfig(max_new_tokens=6, temperature=0.9, top_k=12,
                          top_p=0.9))
        np.testing.assert_array_equal(gw.result(rid_greedy),
                                      _solo(granite, pg, 6))
        toks = gw.result(rid_sampled)
        assert len(toks) == 8 + 6
        assert ((np.asarray(toks) >= 0)
                & (np.asarray(toks) < CFG.vocab_size)).all()

    def test_slo_grading_in_virtual_time(self, granite):
        gw = Gateway(granite, slots=2)
        hit = gw.submit(_prompt(95, 8), 3, deadline_steps=1000)
        miss = gw.submit(_prompt(96, 8), 3, deadline_steps=0)
        gw.result(hit)
        gw.result(miss)
        assert gw.request(hit).slo_met is True
        assert gw.request(miss).slo_met is False
        st = gw.stats()
        assert st["slo_met"] == 1 and st["slo_missed"] == 1

    def test_collect_delivered_bounds_memory(self, granite):
        gw = Gateway(granite, slots=2)
        rids = [gw.submit(_prompt(97 + i, 8), 2) for i in range(2)]
        for rid in rids:
            gw.result(rid)
        done = gw.collect_delivered()
        assert sorted(r.rid for r in done) == sorted(rids)
        assert gw.collect_delivered() == []

    def test_async_stream_and_aresult(self, granite):
        async def scenario():
            gw = Gateway(granite, slots=2)
            await gw.start()
            p = _prompt(99, 8)
            rid = await gw.asubmit(p, 5)
            chunks = []
            async for chunk in gw.stream(rid):
                chunks.append(np.asarray(chunk))
            toks = await gw.aresult(rid)
            await gw.stop()
            return p, rid, chunks, toks

        p, rid, chunks, toks = asyncio.run(scenario())
        want = _solo(granite, p, 5)
        np.testing.assert_array_equal(toks, want)
        # stream carries exactly the generated suffix, in order
        np.testing.assert_array_equal(np.concatenate(chunks), want[8:])


# ---------------------------------------------------------------------------
# traffic traces
# ---------------------------------------------------------------------------

class TestTraffic:
    @pytest.mark.parametrize("mk", [
        lambda s: traffic.poisson_trace(n=16, seed=s),
        lambda s: traffic.bursty_trace(seed=s),
        lambda s: traffic.diurnal_trace(n=16, seed=s),
    ])
    def test_seeded_traces_replay_identically(self, mk):
        a, b = mk(3), mk(3)
        np.testing.assert_array_equal(a.arrivals, b.arrivals)
        np.testing.assert_array_equal(a.lens, b.lens)
        np.testing.assert_array_equal(a.budgets, b.budgets)
        c = mk(4)
        assert (len(a) != len(c)
                or not (np.array_equal(a.arrivals, c.arrivals)
                        and np.array_equal(a.lens, c.lens)
                        and np.array_equal(a.budgets, c.budgets)))

    def test_arrivals_sorted_and_shapes_consistent(self):
        for tr in (traffic.poisson_trace(n=20, seed=0),
                   traffic.bursty_trace(seed=0),
                   traffic.diurnal_trace(n=20, seed=0)):
            assert (np.diff(tr.arrivals) >= 0).all()
            assert len(tr.arrivals) == len(tr.lens) == len(tr.budgets)
            assert (tr.lens > 0).all() and (tr.budgets > 0).all()

    def test_bursty_shape(self):
        tr = traffic.bursty_trace(incumbents=3, long_budget=20, n_bursts=2,
                                  burst=4, gap=10, start=5, seed=0)
        assert len(tr) == 3 + 2 * 4
        assert (tr.arrivals[:3] == 0).all()
        assert (tr.budgets[:3] == 20).all()
        assert set(np.unique(tr.arrivals[3:])) == {5, 15}


# ---------------------------------------------------------------------------
# event-loop responsiveness + the paged gateway path
# ---------------------------------------------------------------------------

class TestServeResponsiveness:
    def test_asubmit_responsive_during_slow_tick(self, granite):
        """``serve()`` runs the tick's compute in a worker thread
        (``asyncio.to_thread``), so a slow decode chunk must NOT block
        ``asubmit``: with every tick pinned to 0.5 s of compute, a submit
        issued mid-tick has to return in a fraction of that."""
        import time

        async def scenario():
            gw = Gateway(granite, slots=2)
            real_tick = gw.loop.tick

            def slow_tick():
                time.sleep(0.5)             # a long decode chunk
                return real_tick()

            gw.loop.tick = slow_tick
            rid0 = await gw.asubmit(_prompt(300, 8), 3)
            await gw.start()
            await asyncio.sleep(0.1)        # serve() is now inside a tick
            t0 = time.monotonic()
            rid1 = await gw.asubmit(_prompt(301, 8), 3)
            elapsed = time.monotonic() - t0
            toks0 = await gw.aresult(rid0)
            toks1 = await gw.aresult(rid1)
            await gw.stop()
            return elapsed, toks0, toks1

        elapsed, toks0, toks1 = asyncio.run(scenario())
        assert elapsed < 0.25, (
            f"asubmit blocked {elapsed:.3f}s behind a 0.5s tick — the "
            "event loop is running tick compute inline")
        np.testing.assert_array_equal(toks0, _solo(granite, _prompt(300, 8), 3))
        np.testing.assert_array_equal(toks1, _solo(granite, _prompt(301, 8), 3))


class TestPagedGateway:
    def test_burst_preempts_with_identity_paged(self, granite):
        """The full gateway stack over a paged pool (page-pressure-aware
        preemption, restore groups bucketed by saved page count) delivers
        byte-identical tokens under an oversubscribed burst."""
        gw = Gateway(granite, slots=2, chunk=2, page_size=8,
                     pages_per_bank=10,
                     preempt=PreemptConfig(min_resident=2, min_remaining=1,
                                           max_parks=3))
        specs = [(310, 9, 10), (311, 12, 8), (312, 8, 6), (313, 10, 7)]
        rids = [gw.submit(_prompt(sd, s), b) for sd, s, b in specs]
        for rid, (sd, s, b) in zip(rids, specs):
            np.testing.assert_array_equal(
                gw.result(rid), _solo(granite, _prompt(sd, s), b))
        assert gw.pool.alloc.page_free_count() == gw.pool.total_pages

    def test_restore_groups_bucket_by_saved_pages(self):
        """Parked sessions with different saved page counts cannot stack
        into one restore launch — the planner must split them."""
        a = _FakeSession(0, 8, phase="parked")
        b = _FakeSession(1, 8, phase="parked")
        c = _FakeSession(2, 8, phase="parked")

        class _PS:
            def __init__(self, n):
                self.n_pages = n

        a.parked, b.parked, c.parked = _PS(2), _PS(3), _PS(2)
        plan = admission.plan([a, b, c])
        groups = {tuple(s.sid for s in g) for g in plan.restores}
        assert groups == {(0, 2), (1,)}
        # whole-row layout: every parked session saves one page -> one group
        a.parked, b.parked, c.parked = _PS(1), _PS(1), _PS(1)
        plan = admission.plan([a, b, c])
        assert [tuple(s.sid for s in g) for g in plan.restores] \
            == [(0, 1, 2)]

    def test_preemptor_acts_on_page_pressure_alone(self, granite):
        """Free slots but an empty page file: the preemptor must still
        park the LRU incumbent so a fresh arrival's page grant fits."""
        pool = granite.session_pool(slots=4, n_banks=1, chunk=2,
                                    page_size=8, pages_per_bank=4)
        pre = Preemptor(pool, PreemptConfig(min_resident=0, min_remaining=0,
                                            max_parks=5))
        a = pool.submit(_prompt(320, 16), 10)          # 3 pages
        pool.step()
        pool.submit(_prompt(321, 8), 20)               # wants 2: only 1 free
        assert pool._free_hint > 0                     # slots are NOT scarce
        assert pre.maybe_preempt() == 1                # ...pages are
        assert pool.table.get(a).phase == "parked"
