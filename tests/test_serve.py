"""Serving: engine generation, CPM KV-cache management, sampling masks,
prompt-lookup speculative decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.serve import Engine, GenConfig, kv_cache, sampling

CFG = all_configs()["granite-8b"].smoke()


@pytest.fixture(scope="module")
def engine():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=128)


def test_greedy_generation_matches_manual_decode(engine):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)
    out, _ = engine.generate({"tokens": tokens}, GenConfig(max_new_tokens=8))
    assert out.shape == (2, 24)
    # manual: prefill + greedy loop
    logits, caches = lm.prefill(engine.params, CFG, {"tokens": tokens}, max_len=128)
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    manual = [cur]
    pos = 16
    for _ in range(7):
        logits, caches = lm.decode_step(engine.params, CFG, cur, caches,
                                        jnp.asarray(pos, jnp.int32))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        manual.append(cur)
        pos += 1
    np.testing.assert_array_equal(np.asarray(out[:, 16:]),
                                  np.concatenate(manual, 1))


def test_spec_decode_matches_greedy(engine):
    """Prompt-lookup speculation must not change greedy output."""
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0, CFG.vocab_size)
    base, _ = engine.generate({"tokens": tokens}, GenConfig(max_new_tokens=10))
    spec, stats = engine.generate({"tokens": tokens},
                                  GenConfig(max_new_tokens=10, ngram_spec=4))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))
    assert stats["proposed"] >= 0


def test_sampling_top_k_mask():
    logits = jnp.array([[1.0, 5.0, 3.0, 2.0, 4.0]])
    m = np.asarray(sampling.top_k_mask(logits, 2))
    np.testing.assert_array_equal(m[0], [False, True, False, False, True])


def test_sampling_top_p_mask():
    probs = jnp.array([[0.5, 0.3, 0.1, 0.06, 0.04]])
    m = np.asarray(sampling.top_p_mask(probs, 0.75))
    assert m[0, 0] and m[0, 1]            # 0.8 mass needed to reach 0.75
    assert not m[0, 3] and not m[0, 4]


def test_sampling_respects_masks():
    logits = jnp.tile(jnp.array([0.0, 10.0, 9.0, -5.0]), (64, 1))
    toks = sampling.sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=2)
    assert set(np.asarray(toks)) <= {1, 2}


class TestKVCacheOps:
    def test_truncate_sets_len(self):
        tree = {"attn": {"k": jnp.zeros((1, 2, 8, 4)), "v": jnp.zeros((1, 2, 8, 4)),
                         "len": jnp.asarray(8)}}
        out = kv_cache.truncate(tree, jnp.asarray(5))
        assert int(out["attn"]["len"]) == 5

    def test_compact_slots(self):
        k = jnp.arange(2 * 1 * 6 * 2, dtype=jnp.float32).reshape(2, 1, 6, 2)
        v = k + 100
        keep = jnp.array([[True, False, True, True, False, True],
                          [True, True, True, False, False, False]])
        ks, vs, ln = kv_cache.compact_slots(k, v, keep)
        np.testing.assert_array_equal(np.asarray(ln), [4, 3])
        np.testing.assert_array_equal(np.asarray(ks)[0, 0, :4, 0],
                                      np.asarray(k)[0, 0, [0, 2, 3, 5], 0])

    def test_evict_by_score_keeps_topk(self):
        k = jnp.arange(1 * 1 * 8 * 2, dtype=jnp.float32).reshape(1, 1, 8, 2)
        v = k
        scores = jnp.array([[0.9, 0.1, 0.8, 0.2, 0.7, 0.3, 0.6, 0.4]])
        ks, vs, ln = kv_cache.evict_by_score(k, v, scores, 4)
        assert int(ln[0]) == 4
        np.testing.assert_array_equal(np.asarray(ks)[0, 0, :4, 0],
                                      np.asarray(k)[0, 0, [0, 2, 4, 6], 0])

    def test_ring_buffer_eviction_is_o1(self):
        """Local-window decode overwrites the oldest slot in place (content-
        movable eviction) — verified via recurrentgemma smoke decode."""
        cfg = all_configs()["recurrentgemma-9b"].smoke()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        caches = lm.init_caches(cfg, 1, max_len=64)
        tok = jnp.zeros((1, 1), jnp.int32)
        # window is cfg.window=16; decode past it and ensure ring reuse
        for t in range(20):
            logits, caches = lm.decode_step(params, cfg, tok, caches,
                                            jnp.asarray(t, jnp.int32))
        ring = caches["blocks"][2]["attn"]["k"]       # attn_local unit slot
        assert ring.shape[-2] == cfg.window           # never grows
        assert np.isfinite(np.asarray(logits, np.float32)).all()
