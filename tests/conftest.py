"""Shared test configuration: vendored `hypothesis` fallback.

`test_core.py` / `test_properties.py` / `test_kv_cache.py` import
`hypothesis` at module scope, which made the whole suite error at
collection in containers that don't ship it.  If the real package is
missing we install a minimal, deterministic shim into ``sys.modules``
before test modules import: `@given` draws a fixed-seed batch of examples
per test (no shrinking, no database — just enough strategy surface for
this repo's property tests).  Installing the real thing
(``pip install -e .[test]``) transparently takes precedence.

The shim caps examples at ``REPRO_SHIM_MAX_EXAMPLES`` (default 10) so the
CPU suite stays fast; the real hypothesis honors each test's own
``max_examples``.
"""

from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

try:                                     # real hypothesis wins if installed
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    _SHIM_CAP = int(os.environ.get("REPRO_SHIM_MAX_EXAMPLES", "10"))

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=1 << 16):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, allow_nan=False,
               allow_infinity=False, width=64):
        def draw(r):
            v = r.uniform(min_value, max_value)
            if width == 32:
                v = float(_np.float32(v))
            return v
        return _Strategy(draw)

    def lists(elements, min_size=0, max_size=None):
        mx = (min_size + 10) if max_size is None else max_size
        return _Strategy(
            lambda r: [elements.draw(r) for _ in range(r.randint(min_size, mx))])

    def text(alphabet="abcdefghij", min_size=0, max_size=None):
        mx = (min_size + 10) if max_size is None else max_size
        chars = list(alphabet)
        return _Strategy(
            lambda r: "".join(r.choice(chars)
                              for _ in range(r.randint(min_size, mx))))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda r: r.choice(items))

    def tuples(*strats):
        return _Strategy(lambda r: tuple(s.draw(r) for s in strats))

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_shim_max_examples", 20), _SHIM_CAP)
                for i in range(n):
                    r = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                    fn(*args, *[s.draw(r) for s in strats], **kwargs)
            # hide strategy-filled params from pytest's fixture resolution:
            # expose only the leading (e.g. `self`) parameters
            params = list(inspect.signature(fn).parameters.values())
            wrapper.__signature__ = inspect.Signature(
                params[: len(params) - len(strats)])
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples       # read at call time
            return fn
        return deco

    _h = types.ModuleType("hypothesis")
    _h.__doc__ = "Minimal deterministic shim (see tests/conftest.py)."
    _st = types.ModuleType("hypothesis.strategies")
    for _name, _obj in [("integers", integers), ("booleans", booleans),
                        ("floats", floats), ("lists", lists), ("text", text),
                        ("sampled_from", sampled_from), ("tuples", tuples)]:
        setattr(_st, _name, _obj)
    _h.given = given
    _h.settings = settings
    _h.strategies = _st
    sys.modules["hypothesis"] = _h
    sys.modules["hypothesis.strategies"] = _st


# ---------------------------------------------------------------------------
# telemetry isolation between test modules
# ---------------------------------------------------------------------------
#
# The obs registry and tracer are process-global by design (a serving
# process has exactly one /metrics endpoint).  Under pytest that design
# leaks state across test modules: a counter bumped by test_gateway.py
# would still be non-zero when test_obs.py snapshots the registry.  This
# autouse fixture resets both at every module boundary.  It deliberately
# uses Registry.reset() (zero values in place) rather than clear():
# serving objects hold live series references via series_property, and
# clearing would orphan them.  Pinned by
# tests/test_obs_live.py::TestRegistryReset.

import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _obs_module_isolation():
    from repro.obs import metrics as _m
    from repro.obs import tracing as _t
    _m.REGISTRY.reset()
    _t.TRACER.clear()
    yield
