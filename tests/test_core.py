"""Unit + property tests for the CPM operator library (`repro.cpm.reference`).

Migrated off the deprecated ``repro.core`` path (PR 4); the legacy shim itself
is covered on purpose in ``tests/test_core_shim.py``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cpm.reference import (comparable, computable, movable,
                                 pe_array, searchable)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Rule 4 — general decoder
# ---------------------------------------------------------------------------

class TestGeneralDecoder:
    def test_basic_range(self):
        m = pe_array.activation_mask(16, 3, 9, 1)
        np.testing.assert_array_equal(np.where(m)[0], np.arange(3, 10))

    def test_carry(self):
        m = pe_array.activation_mask(32, 4, 20, 4)
        np.testing.assert_array_equal(np.where(m)[0], [4, 8, 12, 16, 20])

    @given(st.integers(0, 63), st.integers(0, 63), st.integers(1, 16))
    @settings(max_examples=50, deadline=None)
    def test_fused_equals_three_stage(self, start, end, carry):
        """The paper's carry-pattern -> shift -> all-line decomposition must
        equal the fused O(1) predicate."""
        fused = np.asarray(pe_array.activation_mask(64, start, end, carry))
        staged = np.asarray(pe_array.general_decoder(64, start, end, carry))
        np.testing.assert_array_equal(fused, staged)

    def test_paper_eq_3_1_carry_pattern(self):
        # 3/8 carry-pattern generator for carry=3: D[0], D[3], D[6]
        m = np.asarray(pe_array.carry_pattern(8, 3))
        np.testing.assert_array_equal(np.where(m)[0], [0, 3, 6])


class TestRule6:
    def test_counter_and_priority(self):
        match = jnp.array([False, True, False, True, True])
        assert int(pe_array.count_matches(match)) == 3
        assert int(pe_array.first_match(match)) == 1
        idx, valid = pe_array.enumerate_matches(match, 4)
        np.testing.assert_array_equal(np.asarray(idx), [1, 3, 4, 5])
        np.testing.assert_array_equal(np.asarray(valid), [True, True, True, False])

    def test_no_match(self):
        match = jnp.zeros(7, dtype=bool)
        assert int(pe_array.first_match(match)) == 7
        assert not bool(pe_array.any_match(match))

    def test_enumerate_matches_batched_slices_address_axis(self):
        """PR-3 regression: ``[:max_out]`` used to slice the *batch* axis,
        silently ignoring max_out and breaking the output shape."""
        match = jnp.array([[True, False, True, False, True],
                           [False, False, False, True, False],
                           [False, False, False, False, False]])
        idx, valid = pe_array.enumerate_matches(match, 2)
        assert idx.shape == valid.shape == (3, 2)
        np.testing.assert_array_equal(np.asarray(idx),
                                      [[0, 2], [3, 5], [5, 5]])
        np.testing.assert_array_equal(np.asarray(valid),
                                      [[True, True], [True, False],
                                       [False, False]])


# ---------------------------------------------------------------------------
# Content movable memory
# ---------------------------------------------------------------------------

class TestMovable:
    def test_shift_right(self):
        x = jnp.arange(8)
        out = np.asarray(movable.shift_range(x, 2, 5, 1))
        np.testing.assert_array_equal(out, [0, 1, 2, 2, 3, 4, 5, 7])

    def test_shift_left_with_fill(self):
        x = jnp.arange(8)
        out = np.asarray(movable.shift_range(x, 2, 5, -1, fill=-1))
        np.testing.assert_array_equal(out, [0, 2, 3, 4, 5, -1, 6, 7])

    def test_insert(self):
        x = jnp.array([10, 20, 30, 40, 0, 0, 0, 0])
        out = np.asarray(movable.insert(x, 1, jnp.array([99, 98]), 4))
        np.testing.assert_array_equal(out[:6], [10, 99, 98, 20, 30, 40])

    def test_delete(self):
        x = jnp.array([10, 20, 30, 40, 50, 0, 0, 0])
        out = np.asarray(movable.delete(x, 1, 2, 5))
        np.testing.assert_array_equal(out[:5], [10, 40, 50, 0, 0])

    def test_insert_then_delete_roundtrip(self):
        x = jnp.array([1, 2, 3, 4, 0, 0, 0, 0])
        y = movable.insert(x, 2, jnp.array([7, 8]), 4)
        z = np.asarray(movable.delete(y, 2, 2, 6))
        np.testing.assert_array_equal(z[:4], [1, 2, 3, 4])

    @given(st.lists(st.booleans(), min_size=1, max_size=32))
    @settings(max_examples=40, deadline=None)
    def test_compact_matches_numpy(self, keep):
        keep = np.asarray(keep)
        x = np.arange(len(keep)) + 100
        out, new_len = movable.compact(jnp.asarray(x), jnp.asarray(keep))
        assert int(new_len) == keep.sum()
        np.testing.assert_array_equal(np.asarray(out)[: keep.sum()], x[keep])

    @given(st.integers(2, 6), st.integers(1, 12), st.integers(0, 2 ** 16 - 1))
    @settings(max_examples=40, deadline=None)
    def test_compact_batched_matches_numpy(self, b, n, bits):
        """PR-3 regression: the tail mask used to broadcast ``(B,)`` lengths
        against the batch axis — a crash for B != n and silently wrong rows
        when B == n (exercised here by the b == n cases)."""
        keep = np.array([(bits >> (i % 16)) & 1 for i in range(b * n)],
                        dtype=bool).reshape(b, n)
        x = (np.arange(b * n) + 100).reshape(b, n)
        out, new_len = movable.compact(jnp.asarray(x), jnp.asarray(keep),
                                       fill=-1)
        np.testing.assert_array_equal(np.asarray(new_len), keep.sum(-1))
        for r in range(b):
            kept = keep[r].sum()
            np.testing.assert_array_equal(np.asarray(out)[r, :kept],
                                          x[r][keep[r]])
            np.testing.assert_array_equal(np.asarray(out)[r, kept:],
                                          np.full(n - kept, -1))

    def test_move_object(self):
        x = jnp.arange(10)
        out = np.asarray(movable.move_object(x, 2, 3, 6))
        np.testing.assert_array_equal(out[6:9], [2, 3, 4])
        np.testing.assert_array_equal(out[:6], np.arange(6))


# ---------------------------------------------------------------------------
# Content searchable memory
# ---------------------------------------------------------------------------

class TestSearchable:
    def test_substring_ends(self):
        hay = jnp.array(list(b"abracadabra"), dtype=jnp.int32)
        needle = jnp.array(list(b"abra"), dtype=jnp.int32)
        ends = np.where(np.asarray(searchable.substring_match(hay, needle)))[0]
        np.testing.assert_array_equal(ends, [3, 10])

    def test_find_all_starts(self):
        hay = jnp.array(list(b"aaaa"), dtype=jnp.int32)
        needle = jnp.array(list(b"aa"), dtype=jnp.int32)
        starts, valid = searchable.find_all(hay, needle, 4)
        np.testing.assert_array_equal(np.asarray(starts)[np.asarray(valid)], [0, 1, 2])

    @given(st.text(alphabet="ab", min_size=1, max_size=40),
           st.text(alphabet="ab", min_size=1, max_size=5))
    @settings(max_examples=40, deadline=None)
    def test_matches_python_find(self, hay_s, nee_s):
        if len(nee_s) > len(hay_s):
            return
        hay = jnp.array([ord(c) for c in hay_s], dtype=jnp.int32)
        nee = jnp.array([ord(c) for c in nee_s], dtype=jnp.int32)
        ends = set(np.where(np.asarray(searchable.substring_match(hay, nee)))[0])
        expect = {i + len(nee_s) - 1 for i in range(len(hay_s) - len(nee_s) + 1)
                  if hay_s[i : i + len(nee_s)] == nee_s}
        assert ends == expect

    def test_dynamic_needle_len(self):
        hay = jnp.array(list(b"xabcabz"), dtype=jnp.int32)
        nee = jnp.array(list(b"abc"), dtype=jnp.int32)
        ends = np.where(np.asarray(searchable.substring_match(hay, nee, needle_len=2)))[0]
        np.testing.assert_array_equal(ends, [2, 5])  # "ab" at 1 and 4

    def test_verify_draft(self):
        draft = jnp.array([5, 6, 7, 8])
        target = jnp.array([5, 6, 9, 8])
        assert int(searchable.verify_draft(draft, target)) == 2

    def test_ngram_lookup(self):
        ctx = jnp.array([1, 2, 3, 9, 1, 2, 3], dtype=jnp.int32)
        starts, valid = searchable.ngram_lookup(ctx, jnp.array([1, 2, 3], dtype=jnp.int32))
        got = np.asarray(starts)[np.asarray(valid)]
        np.testing.assert_array_equal(got, [3])  # continuation after first occurrence


# ---------------------------------------------------------------------------
# Content comparable memory
# ---------------------------------------------------------------------------

class TestComparable:
    def test_compare_ops(self):
        x = jnp.array([1, 5, 3, 5])
        assert int(pe_array.count_matches(comparable.compare(x, 5, "eq"))) == 2
        assert int(pe_array.count_matches(comparable.compare(x, 4, "lt"))) == 2

    def test_lex_compare(self):
        words = jnp.array([[1, 9], [2, 0], [1, 2], [2, 1]])  # MSW first
        lt = np.asarray(comparable.lex_compare_lt(words, jnp.array([2, 1])))
        np.testing.assert_array_equal(lt, [True, True, True, False])

    @given(st.lists(st.integers(0, 255), min_size=1, max_size=32))
    @settings(max_examples=30, deadline=None)
    def test_histogram_matches_numpy(self, vals):
        x = jnp.array(vals)
        edges = jnp.array([0, 64, 128, 192, 256])
        h = np.asarray(comparable.histogram(x, edges))
        np.testing.assert_array_equal(h, np.histogram(vals, bins=np.asarray(edges))[0])

    def test_quantile_threshold_topk(self):
        x = jnp.linspace(0.0, 1.0, 100)
        t = comparable.quantile_threshold(x, 10, 0.0, 1.0)
        assert int((x > t).sum()) in (9, 10)

    @given(st.integers(1, 8), st.integers(0, 6))
    @settings(max_examples=30, deadline=None)
    def test_topk_mask(self, k, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (3, 12))
        m = comparable.topk_mask(x, k)
        assert np.all(np.asarray(m.sum(-1)) == k)
        # masked-in values must all be >= every masked-out value
        lo = np.where(np.asarray(m), np.asarray(x), np.inf).min(-1)
        hi = np.where(np.asarray(m), -np.inf, np.asarray(x)).max(-1)
        assert np.all(lo >= hi - 1e-6)


# ---------------------------------------------------------------------------
# Content computable memory
# ---------------------------------------------------------------------------

class TestComputable:
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=300))
    @settings(max_examples=30, deadline=None)
    def test_section_sum(self, vals):
        x = jnp.array(vals, dtype=jnp.float32)
        np.testing.assert_allclose(float(computable.section_sum(x)),
                                   np.sum(np.asarray(x, dtype=np.float64)),
                                   rtol=1e-4, atol=1e-3)

    def test_section_sum_steps_sqrtN(self):
        n = 4096
        assert computable.section_sum_steps(n) <= 2 * int(np.sqrt(n)) + 1

    def test_section_limit(self):
        x = jnp.array([3.0, -7.0, 11.0, 0.5])
        assert float(computable.section_limit(x, mode="max")) == 11.0
        assert float(computable.section_limit(x, mode="min")) == -7.0

    def test_section_sum_2d(self):
        x = jnp.arange(48, dtype=jnp.float32).reshape(6, 8)
        np.testing.assert_allclose(float(computable.section_sum_2d(x)), x.sum())

    def test_stencil_algebra_eq_7_10(self):
        """(1 2 1) == (1 1 0) # (0 1 1)."""
        got = computable.compose_taps([1, 1, 0], [0, 1, 1])
        np.testing.assert_array_equal(np.trim_zeros(got), [1, 2, 1])

    def test_stencil_algebra_eq_7_11(self):
        """(1 2 4 2 1) == (1 1 1)#(1 1 1) + (1)  — 5-pt Gaussian, 6 cycles."""
        got = computable.add_taps(computable.compose_taps([1, 1, 1], [1, 1, 1]), [1])
        np.testing.assert_array_equal(got, [1, 2, 4, 2, 1])

    def test_stencil_1d_gaussian(self):
        x = jnp.array([0.0, 0, 1, 0, 0])
        y = np.asarray(computable.stencil_1d(x, [1, 2, 1]))
        np.testing.assert_allclose(y[1:4], [1, 2, 1])

    def test_stencil_2d_eq_7_12(self):
        taps = computable.compose_taps([1, 1, 0], [0, 1, 1])
        t2d = np.outer([1, 2, 1], [1, 2, 1]) / 1
        x = jnp.zeros((7, 7)).at[3, 3].set(1.0)
        y = np.asarray(computable.stencil_2d(x, t2d))
        np.testing.assert_allclose(y[2:5, 2:5], t2d)

    @given(st.lists(st.floats(-50, 50, allow_nan=False, width=32),
                    min_size=2, max_size=64))
    @settings(max_examples=25, deadline=None)
    def test_odd_even_full_sort(self, vals):
        x = jnp.array(vals, dtype=jnp.float32)
        out = np.asarray(computable.odd_even_sort(x))
        np.testing.assert_allclose(out, np.sort(vals), rtol=1e-6)

    @given(st.lists(st.floats(-50, 50, allow_nan=False, width=32),
                    min_size=2, max_size=48))
    @settings(max_examples=20, deadline=None)
    def test_hybrid_sort(self, vals):
        x = jnp.array(vals, dtype=jnp.float32)
        out = np.asarray(computable.hybrid_sort(x))
        np.testing.assert_allclose(out, np.sort(vals), rtol=1e-6)

    def test_count_disorder(self):
        assert int(computable.count_disorder(jnp.array([1, 2, 3]))) == 0
        assert int(computable.count_disorder(jnp.array([3, 2, 1]))) == 2

    def test_detect_defects_peak_valley(self):
        x = jnp.array([1.0, 2, 9, 3, 4])     # 9 is a peak
        d = computable.detect_defects(x)
        assert bool(d["peak"][2])
        x = jnp.array([5.0, 6, 1, 7, 8])     # 1 is a valley
        d = computable.detect_defects(x)
        assert bool(d["valley"][2])

    def test_template_match_1d(self):
        data = jnp.array([9.0, 1, 2, 3, 9, 9, 1, 2, 3, 9])
        t = jnp.array([1.0, 2, 3])
        sad = np.asarray(computable.template_match_1d(data, t))
        assert sad[1] == 0 and sad[6] == 0
        assert np.all(sad[[0, 2, 3, 4, 5]] > 0)

    def test_template_match_2d(self):
        img = jnp.zeros((8, 8)).at[2:4, 3:5].set(jnp.array([[1.0, 2], [3, 4]]))
        t = jnp.array([[1.0, 2], [3, 4]])
        sad = np.asarray(computable.template_match_2d(img, t))
        assert sad[2, 3] == 0
        assert np.count_nonzero(sad == 0) == 1

    def test_line_detection_prefers_edge(self):
        img = jnp.zeros((16, 16)).at[8:, :].set(1.0)  # horizontal edge
        resp = np.asarray(computable.edge_along_x(img, 4))
        # interior rows only (roll wraps at the image border)
        assert np.abs(resp[7:9]).max() > np.abs(resp[3:6]).max()
