"""`repro.cpm.pool` — banks, the self-managing allocator, the MASIM packer.

Covers the pool subsystem's contracts:

  * the page-table allocator (whose free-list/victim lookups are CPM
    compare/limit ops) never double-books a page, never leaks one, and
    agrees with a naive Python oracle over random alloc/free/touch
    sequences (hypothesis);
  * bank page movement (scalar-prefetch gather/scatter kernels on pallas)
    is identical to the reference jnp realization;
  * the multi-bank scheduler packs per-slot streams into ONE batched
    launch per bank (fused on pallas, jaxpr-asserted), leaves idle rows'
    live regions bit-untouched, and rejects malformed packings.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cpm.pool import (CPMBank, MultiBankScheduler, OracleAllocator,
                            SessionTable, SlotAllocator)
from repro.cpm.program import count_pallas_calls

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# allocator: CPM bookkeeping vs the Python oracle
# ---------------------------------------------------------------------------

class TestSlotAllocator:
    def test_alloc_until_full_then_none(self):
        a = SlotAllocator(3)
        assert [a.alloc() for _ in range(4)] == [0, 1, 2, None]
        assert a.free_count() == 0 and a.used_count() == 3

    def test_free_then_lowest_first(self):
        a = SlotAllocator(4)
        for _ in range(4):
            a.alloc()
        a.free(2)
        a.free(0)
        assert a.alloc() == 0          # lowest free page wins (priority enc)
        assert a.alloc() == 2

    def test_double_free_raises(self):
        a = SlotAllocator(2)
        a.alloc()
        a.free(0)
        with pytest.raises(ValueError, match="double free"):
            a.free(0)

    def test_victim_is_lru(self):
        a = SlotAllocator(3)
        for _ in range(3):
            a.alloc()
        a.touch(0)                     # slot 1 is now the oldest
        assert a.victim() == 1
        a.touch(1)
        assert a.victim() == 2

    def test_victim_empty_pool(self):
        assert SlotAllocator(2).victim() is None

    def test_used_slots_packed_via_compact(self):
        a = SlotAllocator(5)
        for _ in range(4):
            a.alloc()
        a.free(1)
        a.free(3)
        assert a.used_slots() == [0, 2]

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_victim_tie_break_is_lowest_slot(self, backend):
        """Equal allocation ticks (forced directly — the public API keeps
        ticks unique via the clock) must break deterministically to the
        lowest used slot on every backend: enumerate_matches drains
        §6-Rule-6 style, lowest address first."""
        kw = {"backend": backend, "interpret": True} \
            if backend == "pallas" else {}
        a = SlotAllocator(4, **kw)
        for _ in range(4):
            a.alloc()
        a.free(0)                           # slots 1..3 used
        a._tick = jnp.full((4,), 7, jnp.int32)   # three-way tie
        assert a.victim() == 1
        a.free(1)
        assert a.victim() == 2

    @given(st.lists(st.integers(0, 9), min_size=4, max_size=4),
           st.lists(st.booleans(), min_size=4, max_size=4))
    @settings(max_examples=10, deadline=None)
    def test_victim_ties_match_naive_min_across_backends(self, ticks, used):
        """Arbitrary (possibly tying) tick vectors: both backends must
        pick min-tick-then-min-slot, the same answer a naive host scan
        gives."""
        n = 4
        want = min((t, s) for s, (t, u) in enumerate(zip(ticks, used))
                   if u)[1] if any(used) else None
        for kw in ({}, {"backend": "pallas", "interpret": True}):
            a = SlotAllocator(n, **kw)
            # force the exact occupancy/tick pattern under test
            a._state = jnp.asarray([1 if u else 0 for u in used], jnp.int32)
            a._tick = jnp.asarray(ticks, jnp.int32)
            assert a.victim() == want

    @given(st.lists(st.integers(0, 2), min_size=1, max_size=60))
    @settings(max_examples=20, deadline=None)
    def test_matches_oracle_never_double_books_never_leaks(self, moves):
        """Random alloc/free/touch trace: the CPM allocator and the Python
        oracle make identical decisions, no page is handed out twice, and
        free + used always covers the pool exactly."""
        n = 4
        cpm, orc = SlotAllocator(n), OracleAllocator(n)
        held: set[int] = set()
        for i, mv in enumerate(moves):
            if mv == 0:                                   # alloc
                got, want = cpm.alloc(), orc.alloc()
                assert got == want
                if got is not None:
                    assert got not in held                # never double-booked
                    held.add(got)
            elif mv == 1 and held:                        # free (deterministic
                slot = sorted(held)[i % len(held)]        # pick from the trace)
                cpm.free(slot)
                orc.free(slot)
                held.discard(slot)
            elif mv == 2 and held:                        # touch
                slot = sorted(held)[i % len(held)]
                cpm.touch(slot)
                orc.touch(slot)
            assert cpm.free_count() == orc.free_count() == n - len(held)
            assert cpm.used_slots() == orc.used_slots() == sorted(held)
            assert cpm.victim() == orc.victim()


# ---------------------------------------------------------------------------
# sub-page file: page-list allocation as CPM ops
# ---------------------------------------------------------------------------

class TestPagedAllocator:
    def test_alloc_pages_lowest_first_in_range(self):
        a = SlotAllocator(2, n_pages=8)
        s = a.alloc()
        assert a.alloc_pages(s, 2, 4, 8) == [4, 5]     # bank-1 range only
        assert a.alloc_pages(s, 1) == [0]              # global: lowest free
        assert a.pages(s) == [4, 5, 0]                 # ordered by grant
        assert a.page_free_count() == 5
        assert a.page_free_count(4, 8) == 2

    def test_alloc_pages_all_or_nothing(self):
        a = SlotAllocator(1, n_pages=4)
        s = a.alloc()
        assert a.alloc_pages(s, 3) == [0, 1, 2]
        assert a.alloc_pages(s, 2) is None             # only 1 left: claim
        assert a.page_free_count() == 1                # NOTHING of it
        assert a.pages(s) == [0, 1, 2]
        assert a.alloc_pages(s, 1) == [3]

    def test_pages_need_a_used_owner(self):
        a = SlotAllocator(2, n_pages=4)
        with pytest.raises(ValueError, match="owner"):
            a.alloc_pages(0, 1)
        s = a.alloc()
        with pytest.raises(ValueError, match="positive"):
            a.alloc_pages(s, 0)
        with pytest.raises(IndexError):
            a.alloc_pages(s, 1, 2, 9)                  # range out of bounds

    def test_free_releases_whole_page_list(self):
        a = SlotAllocator(2, n_pages=6)
        s0, s1 = a.alloc(), a.alloc()
        a.alloc_pages(s0, 3)
        a.alloc_pages(s1, 2)
        a.free(s0)                                     # retire: slot + pages
        assert a.page_free_count() == 4
        assert a.pages(s1) == [3, 4]                   # neighbor untouched
        s2 = a.alloc()
        assert a.alloc_pages(s2, 3) == [0, 1, 2]       # reclaimed, lowest-first

    def test_no_page_file_is_inert(self):
        a = SlotAllocator(2)                           # n_pages=0 default
        s = a.alloc()
        assert a.page_free_count() == 0
        assert a.pages(s) == []
        a.free(s)                                      # nothing to leak

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                    min_size=1, max_size=60))
    @settings(max_examples=20, deadline=None)
    def test_page_traces_match_oracle_no_double_booking_no_leaks(self, moves):
        """Random alloc / alloc_pages(extend) / free(park-or-retire) /
        touch traces: the CPM allocator and the oracle hand out identical
        page lists, no sub-page is ever owned twice, and freeing a slot
        (retire, cancel and park all route through ``free``) returns its
        whole list — free + owned always covers the page file exactly."""
        n, npg = 3, 8
        cpm = SlotAllocator(n, n_pages=npg)
        orc = OracleAllocator(n, n_pages=npg)
        held: set[int] = set()
        for i, (mv, arg) in enumerate(moves):
            if mv == 0:                                   # alloc slot
                got, want = cpm.alloc(), orc.alloc()
                assert got == want
                if got is not None:
                    held.add(got)
            elif mv == 1 and held:                        # extend page list
                slot = sorted(held)[i % len(held)]
                k = 1 + arg % 3
                lo = (arg % 2) * (npg // 2)               # one bank's range
                got = cpm.alloc_pages(slot, k, lo, lo + npg // 2)
                want = orc.alloc_pages(slot, k, lo, lo + npg // 2)
                assert got == want                        # incl. both-None
            elif mv == 2 and held:                        # free = park/retire
                slot = sorted(held)[i % len(held)]
                cpm.free(slot)
                orc.free(slot)
                held.discard(slot)
            elif mv == 3 and held:                        # touch
                slot = sorted(held)[i % len(held)]
                cpm.touch(slot)
                orc.touch(slot)
            owned = [p for s in held for p in orc.pages(s)]
            assert len(owned) == len(set(owned))          # never double-booked
            for s in sorted(held):
                assert cpm.pages(s) == orc.pages(s)       # identical lists
            # free + owned covers the file exactly: nothing leaked
            assert (cpm.page_free_count() == orc.page_free_count()
                    == npg - len(owned))
            booked = set(np.flatnonzero(cpm.page_state_vector()))
            assert booked == set(owned)
            assert cpm.victim() == orc.victim()


# ---------------------------------------------------------------------------
# banks: paged row movement, reference vs pallas kernels
# ---------------------------------------------------------------------------

class TestCPMBank:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_write_read_roundtrip(self, backend):
        b = CPMBank(4, 16, backend=backend, interpret=True)
        b.write_row(2, jnp.arange(5) + 1)
        row, ln = b.read_row(2)
        assert ln == 5
        np.testing.assert_array_equal(row[:5], [1, 2, 3, 4, 5])
        assert (row[5:] == 0).all()
        b.clear_row(2)
        assert b.read_row(2)[1] == 0

    def test_gather_scatter_pallas_matches_reference(self):
        key = jax.random.PRNGKey(0)
        data = jax.random.randint(key, (6, 32), 0, 100)
        lens = jnp.arange(6, dtype=jnp.int32) + 3
        ref = CPMBank(6, 32)
        pal = CPMBank(6, 32, backend="pallas", interpret=True)
        for b in (ref, pal):
            b.data, b.lens = data, lens
        idx = jnp.asarray([4, 0, 2], jnp.int32)
        np.testing.assert_array_equal(np.asarray(ref.gather(idx)),
                                      np.asarray(pal.gather(idx)))
        rows = jax.random.randint(jax.random.PRNGKey(1), (3, 32), 0, 100)
        new_lens = jnp.asarray([7, 8, 9], jnp.int32)
        ref.scatter(idx, rows, new_lens)
        pal.scatter(idx, rows, new_lens)
        np.testing.assert_array_equal(np.asarray(ref.data),
                                      np.asarray(pal.data))
        np.testing.assert_array_equal(np.asarray(ref.lens),
                                      np.asarray(pal.lens))
        # untouched pages kept their content
        np.testing.assert_array_equal(np.asarray(ref.data[1]),
                                      np.asarray(data[1]))

    def test_row_too_wide_raises(self):
        with pytest.raises(ValueError, match="width"):
            CPMBank(2, 4).write_row(0, jnp.arange(5))


# ---------------------------------------------------------------------------
# MASIM packer: one batched launch per bank
# ---------------------------------------------------------------------------

def _commit(used, tok):
    return [("insert", {"pos": used, "values": jnp.asarray([tok])}),
            ("truncate", {"new_len": used + 1})]


class TestMultiBankScheduler:
    def test_partial_bank_idle_rows_untouched(self):
        b = CPMBank(4, 12)
        for slot in range(4):
            b.write_row(slot, jnp.full((3,), 10 + slot), 3)
        before = np.asarray(b.data).copy()
        sched = MultiBankScheduler([b])
        for slot in (1, 3):
            sched.submit(0, slot, _commit(b.lens[slot], 90 + slot))
        assert sched.flush() == {"banks": 1, "streams": 2}
        for slot in (1, 3):
            row, ln = b.read_row(slot)
            assert ln == 4 and row[3] == 90 + slot
        for slot in (0, 2):                     # idle pages: live region
            row, ln = b.read_row(slot)          # bit-untouched, length kept
            assert ln == 3
            np.testing.assert_array_equal(row[:3], before[slot, :3])

    def test_full_bank_out_of_slot_order(self):
        """Regression: a full bank's operands must scatter by slot, not
        ride in queue order."""
        b = CPMBank(3, 8)
        sched = MultiBankScheduler([b])
        for slot in (2, 0, 1):                  # deliberately shuffled
            sched.submit(0, slot, _commit(b.lens[slot], 50 + slot))
        sched.flush()
        for slot in range(3):
            row, ln = b.read_row(slot)
            assert ln == 1 and row[0] == 50 + slot

    def test_multi_bank_routing_and_counters(self):
        banks = [CPMBank(2, 8), CPMBank(2, 8)]
        sched = MultiBankScheduler(banks)
        sched.submit(0, 0, _commit(banks[0].lens[0], 7))
        sched.submit(1, 1, _commit(banks[1].lens[1], 8))
        assert sched.flush() == {"banks": 2, "streams": 2}
        assert banks[0].read_row(0)[0][0] == 7
        assert banks[1].read_row(1)[0][0] == 8
        assert sched.bank_launches == 2 and sched.streams_packed == 2
        assert sched.flush() == {"banks": 0, "streams": 0}   # empty is fine

    def test_mixed_templates_raise(self):
        b = CPMBank(2, 8)
        sched = MultiBankScheduler([b])
        sched.submit(0, 0, _commit(b.lens[0], 1))
        sched.submit(0, 1, [("truncate", {"new_len": 0})])
        with pytest.raises(ValueError, match="template"):
            sched.flush()

    def test_partially_bound_operand_raises(self):
        """A dynamic operand supplied by only some streams must fail with
        the packing diagnostic, not a deep stacking TypeError."""
        b = CPMBank(2, 8)
        sched = MultiBankScheduler([b])
        sched.submit(0, 0, [("truncate", {"new_len": 3})])
        sched.submit(0, 1, [("truncate", {})])
        with pytest.raises(ValueError, match="dynamic operands"):
            sched.flush()

    def test_same_slot_twice_raises(self):
        b = CPMBank(2, 8)
        sched = MultiBankScheduler([b])
        sched.submit(0, 0, _commit(b.lens[0], 1))
        sched.submit(0, 0, _commit(b.lens[0], 2))
        with pytest.raises(ValueError, match="slot"):
            sched.flush()

    def test_array_static_operand_rejected(self):
        b = CPMBank(2, 8)
        sched = MultiBankScheduler([b])
        sched.submit(0, 0, [("insert", {"pos": b.lens[0],
                                        "values": jnp.asarray([1])}),
                            ("shift", {"start": 0, "end": 1,
                                       "shift": jnp.asarray(1)})])
        with pytest.raises(TypeError, match="static operands"):
            sched.flush()

    def test_pallas_bank_commit_is_one_fused_launch(self):
        """The packed insert->truncate template on a pallas bank lowers to
        exactly ONE fused_stream mega-kernel launch per flush — the MASIM
        claim in jaxpr terms."""
        def run(data, lens, toks):
            bank = CPMBank(4, 16, backend="pallas", interpret=True)
            bank.data, bank.lens = data, lens
            sched = MultiBankScheduler([bank])
            for slot in range(3):               # 3 of 4 slots commit
                sched.submit(0, slot, _commit(lens[slot], toks[slot]))
            sched.flush()
            return bank.data, bank.lens

        data = jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0, 50)
        lens = jnp.asarray([3, 5, 0, 2], jnp.int32)
        toks = jnp.asarray([91, 92, 93, 94], jnp.int32)
        assert count_pallas_calls(run, data, lens, toks) == 1

        # and the pallas lowering matches the reference packer bit-for-bit
        pal_data, pal_lens = run(data, lens, toks)

        def run_ref(data, lens, toks):
            bank = CPMBank(4, 16)
            bank.data, bank.lens = data, lens
            sched = MultiBankScheduler([bank])
            for slot in range(3):
                sched.submit(0, slot, _commit(lens[slot], toks[slot]))
            sched.flush()
            return bank.data, bank.lens

        ref_data, ref_lens = run_ref(data, lens, toks)
        np.testing.assert_array_equal(np.asarray(pal_lens),
                                      np.asarray(ref_lens))
        for r in range(4):                      # identical live regions
            n = int(ref_lens[r])
            np.testing.assert_array_equal(np.asarray(pal_data)[r, :n],
                                          np.asarray(ref_data)[r, :n])


# ---------------------------------------------------------------------------
# session table: lifecycle plumbing
# ---------------------------------------------------------------------------

class TestSessionTable:
    def test_fifo_lifecycle(self):
        t = SessionTable()
        a = t.add(jnp.arange(3), 3, 5)
        b = t.add(jnp.arange(4), 4, 2)
        assert t.next_waiting() is a
        t.activate(a.sid, 0, 1)
        assert t.at_slot(1) is a and t.next_waiting() is b
        assert t.active_count() == 1 and t.waiting_count() == 1
        t.finish(a.sid, np.arange(8))
        assert t.at_slot(1) is None
        t.activate(b.sid, 0, 0)
        t.finish(b.sid, np.arange(6))
        assert t.all_done()
        assert set(t.outputs()) == {a.sid, b.sid}
