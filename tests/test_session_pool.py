"""Continuous batching vs per-session static generation — differential.

The pool's contract: under greedy decoding, every session drained through
the paged pool is **token-identical** to running it alone through the
static scan engine — across ragged prompt lengths, ragged budgets,
oversubscription (more sessions than pages), multi-bank splits, and the
hybrid recurrent architecture.  Plus the engine's compiled-program cache
keying regression (shapes must key the cache, not just names).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.serve import Engine, GenConfig

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()
HYB = all_configs()["recurrentgemma-9b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


@pytest.fixture(scope="module")
def hybrid():
    params = lm.init_params(HYB, jax.random.PRNGKey(0))
    return Engine(HYB, params, max_len=48)


def _prompt(seed, s, cfg):
    return jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                              cfg.vocab_size)


def _solo(engine, prompt, budget):
    out, _ = engine.generate({"tokens": prompt[None]},
                             GenConfig(max_new_tokens=budget))
    return np.asarray(out[0])


# ---------------------------------------------------------------------------
# token identity
# ---------------------------------------------------------------------------

class TestPoolTokenIdentity:
    def test_oversubscribed_ragged_matches_solo(self, granite):
        """6 sessions over 4 pages (2 banks), ragged prompts AND budgets:
        every drained output equals its solo static generation."""
        lens = [8, 12, 10, 8, 16, 9]
        budgets = [5, 12, 3, 9, 1, 7]
        prompts = [_prompt(i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]

        pool = granite.session_pool(slots=4, n_banks=2)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)
        stats = pool.stats()
        assert stats["emitted"] == sum(budgets)
        assert 0.0 < stats["occupancy"] <= 1.0

    def test_single_bank_matches_solo(self, granite):
        prompts = [_prompt(10 + i, 8, CFG) for i in range(3)]
        pool = granite.session_pool(slots=2, n_banks=1)
        sids = [pool.submit(p, 6) for p in prompts]
        outs = pool.drain()
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 6))

    def test_hybrid_arch_matches_solo(self, hybrid):
        """Recurrent (rglru) state + local-window rings page in and out of
        the pool rows without perturbing other sessions."""
        lens, budgets = [10, 14, 10], [6, 3, 8]
        prompts = [_prompt(20 + i, s, HYB) for i, s in enumerate(lens)]
        want = [_solo(hybrid, p, b) for p, b in zip(prompts, budgets)]
        pool = hybrid.session_pool(slots=2)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)

    def test_late_arrivals_match_solo(self, granite):
        """Sessions submitted mid-flight join free pages without touching
        in-flight rows."""
        first = [_prompt(30 + i, 8, CFG) for i in range(2)]
        late = [_prompt(40 + i, 11, CFG) for i in range(2)]
        pool = granite.session_pool(slots=2)
        sids = [pool.submit(p, 8) for p in first]
        pool.step()
        pool.step()
        sids += [pool.submit(p, 4) for p in late]
        outs = pool.drain()
        for sid, (p, b) in zip(sids, [(p, 8) for p in first]
                               + [(p, 4) for p in late]):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, b))

    @pytest.mark.parametrize("chunk", [3, 8])
    def test_chunked_decode_matches_solo(self, granite, chunk):
        """Decoding ``chunk`` tokens per compiled step (sessions finishing
        mid-chunk overshoot into slack; the commit clamps to budget) emits
        the identical tokens at any chunk size."""
        lens = [8, 12, 10, 9]
        budgets = [5, 11, 2, 7]               # none a multiple of chunk
        prompts = [_prompt(90 + i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]
        pool = granite.session_pool(slots=2, chunk=chunk)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)

    def test_pallas_banks_match_reference_banks(self, granite):
        """Token pages on pallas banks (fused commit launches + DMA
        gather/scatter kernels) drain the identical tokens."""
        prompts = [_prompt(50 + i, 9, CFG) for i in range(3)]
        ref = granite.session_pool(slots=2)
        pal = granite.session_pool(slots=2, bank_backend="pallas",
                                   bank_interpret=True)
        for p in prompts:
            ref.submit(p, 5)
            pal.submit(p, 5)
        r, q = ref.drain(), pal.drain()
        for sid in r:
            np.testing.assert_array_equal(r[sid], q[sid])


# ---------------------------------------------------------------------------
# lifecycle / API edges
# ---------------------------------------------------------------------------

class TestPoolLifecycle:
    def test_zero_budget_rejected(self, granite):
        """A degenerate budget is a caller error, not a no-op session —
        rejected before it can occupy queue or page state."""
        pool = granite.session_pool(slots=2)
        p = _prompt(60, 7, CFG)
        with pytest.raises(ValueError, match="must be positive"):
            pool.submit(p, 0)
        with pytest.raises(ValueError, match="must be positive"):
            pool.submit(p, -3)
        assert len(pool.table) == 0

    def test_empty_prompt_rejected(self, granite):
        pool = granite.session_pool(slots=2)
        with pytest.raises(ValueError, match="empty prompt"):
            pool.submit(np.zeros((0,), np.int32), 4)
        assert len(pool.table) == 0

    def test_budget_one_is_the_prefill_token(self, granite):
        pool = granite.session_pool(slots=2)
        p = _prompt(61, 7, CFG)
        sid = pool.submit(p, 1)
        outs = pool.drain()
        np.testing.assert_array_equal(outs[sid], _solo(granite, p, 1))

    def test_overlong_request_rejected(self, granite):
        pool = granite.session_pool(slots=2)
        with pytest.raises(ValueError, match="max_len"):
            pool.submit(_prompt(62, 60, CFG), 10)

    def test_pages_reclaimed(self, granite):
        pool = granite.session_pool(slots=2)
        for i in range(4):
            pool.submit(_prompt(70 + i, 8, CFG), 2)
        pool.drain()
        assert pool.alloc.free_count() == 2       # all pages back
        assert pool.table.all_done()

    def test_engine_submit_step_drain_facade(self, granite):
        params = lm.init_params(CFG, jax.random.PRNGKey(0))
        eng = Engine(CFG, params, max_len=64)
        p = _prompt(80, 8, CFG)
        sid = eng.submit(p, 3, slots=2)
        stats = eng.step()
        assert stats["emitted"] >= 1
        outs = eng.drain()
        np.testing.assert_array_equal(outs[sid], _solo(eng, p, 3))

    def test_bad_shapes_rejected(self, granite):
        with pytest.raises(ValueError, match="multiple"):
            granite.session_pool(slots=3, n_banks=2)

    def test_drain_delivers_each_session_once(self, granite):
        """Delivered sessions are evicted — a later drain returns only
        sessions finished since the last one (bounded table memory under
        a continuous stream)."""
        pool = granite.session_pool(slots=2)
        a = pool.submit(_prompt(85, 8, CFG), 2)
        first = pool.drain()
        assert set(first) == {a}
        b = pool.submit(_prompt(86, 8, CFG), 2)
        second = pool.drain()
        assert set(second) == {b}
        assert len(pool.table) == 0


# ---------------------------------------------------------------------------
# compiled-program cache keying (regression)
# ---------------------------------------------------------------------------

class TestProgramCacheKeying:
    def test_same_name_different_shapes_do_not_collide(self, granite):
        """Two builders under one name with different static shape args
        must compile separately — colliding returned the first shape's
        program for the second shape (the pool drives varying row counts
        through one engine)."""
        calls = []

        def builder(s):
            calls.append(s)
            return lambda: s

        gen = GenConfig(max_new_tokens=4)
        a = granite._program("probe", gen, builder, 8)
        b = granite._program("probe", gen, builder, 12)
        assert (a(), b()) == (8, 12)
        assert calls == [8, 12]
        # and the cache still memoizes identical keys
        assert granite._program("probe", gen, builder, 8) is a
        assert calls == [8, 12]

    def test_genconfig_arg_keys_via_key(self, granite):
        def builder(g):
            return lambda: g.max_new_tokens

        g1, g2 = GenConfig(max_new_tokens=4), GenConfig(max_new_tokens=9)
        assert granite._program("probe2", g1, builder, g1)() == 4
        assert granite._program("probe2", g2, builder, g2)() == 9

    def test_unhashable_builder_arg_rejected(self, granite):
        with pytest.raises(TypeError, match="statically hashable"):
            granite._program("probe3", GenConfig(), lambda a: a,
                             jnp.zeros((3,)))
