"""Continuous batching vs per-session static generation — differential.

The pool's contract: under greedy decoding, every session drained through
the paged pool is **token-identical** to running it alone through the
static scan engine — across ragged prompt lengths, ragged budgets,
oversubscription (more sessions than pages), multi-bank splits, and the
hybrid recurrent architecture.  Plus the engine's compiled-program cache
keying regression (shapes must key the cache, not just names).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.serve import Engine, GenConfig

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()
HYB = all_configs()["recurrentgemma-9b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


@pytest.fixture(scope="module")
def hybrid():
    params = lm.init_params(HYB, jax.random.PRNGKey(0))
    return Engine(HYB, params, max_len=48)


def _prompt(seed, s, cfg):
    return jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                              cfg.vocab_size)


def _solo(engine, prompt, budget):
    out, _ = engine.generate({"tokens": prompt[None]},
                             GenConfig(max_new_tokens=budget))
    return np.asarray(out[0])


# ---------------------------------------------------------------------------
# token identity
# ---------------------------------------------------------------------------

class TestPoolTokenIdentity:
    def test_oversubscribed_ragged_matches_solo(self, granite):
        """6 sessions over 4 pages (2 banks), ragged prompts AND budgets:
        every drained output equals its solo static generation."""
        lens = [8, 12, 10, 8, 16, 9]
        budgets = [5, 12, 3, 9, 1, 7]
        prompts = [_prompt(i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]

        pool = granite.session_pool(slots=4, n_banks=2)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)
        stats = pool.stats()
        assert stats["emitted"] == sum(budgets)
        assert 0.0 < stats["occupancy"] <= 1.0

    def test_single_bank_matches_solo(self, granite):
        prompts = [_prompt(10 + i, 8, CFG) for i in range(3)]
        pool = granite.session_pool(slots=2, n_banks=1)
        sids = [pool.submit(p, 6) for p in prompts]
        outs = pool.drain()
        for sid, p in zip(sids, prompts):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, 6))

    def test_hybrid_arch_matches_solo(self, hybrid):
        """Recurrent (rglru) state + local-window rings page in and out of
        the pool rows without perturbing other sessions."""
        lens, budgets = [10, 14, 10], [6, 3, 8]
        prompts = [_prompt(20 + i, s, HYB) for i, s in enumerate(lens)]
        want = [_solo(hybrid, p, b) for p, b in zip(prompts, budgets)]
        pool = hybrid.session_pool(slots=2)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)

    def test_late_arrivals_match_solo(self, granite):
        """Sessions submitted mid-flight join free pages without touching
        in-flight rows."""
        first = [_prompt(30 + i, 8, CFG) for i in range(2)]
        late = [_prompt(40 + i, 11, CFG) for i in range(2)]
        pool = granite.session_pool(slots=2)
        sids = [pool.submit(p, 8) for p in first]
        pool.step()
        pool.step()
        sids += [pool.submit(p, 4) for p in late]
        outs = pool.drain()
        for sid, (p, b) in zip(sids, [(p, 8) for p in first]
                               + [(p, 4) for p in late]):
            np.testing.assert_array_equal(outs[sid], _solo(granite, p, b))

    @pytest.mark.parametrize("chunk", [3, 8])
    def test_chunked_decode_matches_solo(self, granite, chunk):
        """Decoding ``chunk`` tokens per compiled step (sessions finishing
        mid-chunk overshoot into slack; the commit clamps to budget) emits
        the identical tokens at any chunk size."""
        lens = [8, 12, 10, 9]
        budgets = [5, 11, 2, 7]               # none a multiple of chunk
        prompts = [_prompt(90 + i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]
        pool = granite.session_pool(slots=2, chunk=chunk)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)

    def test_pallas_banks_match_reference_banks(self, granite):
        """Token pages on pallas banks (fused commit launches + DMA
        gather/scatter kernels) drain the identical tokens."""
        prompts = [_prompt(50 + i, 9, CFG) for i in range(3)]
        ref = granite.session_pool(slots=2)
        pal = granite.session_pool(slots=2, bank_backend="pallas",
                                   bank_interpret=True)
        for p in prompts:
            ref.submit(p, 5)
            pal.submit(p, 5)
        r, q = ref.drain(), pal.drain()
        for sid in r:
            np.testing.assert_array_equal(r[sid], q[sid])


# ---------------------------------------------------------------------------
# lifecycle / API edges
# ---------------------------------------------------------------------------

class TestPoolLifecycle:
    def test_zero_budget_rejected(self, granite):
        """A degenerate budget is a caller error, not a no-op session —
        rejected before it can occupy queue or page state."""
        pool = granite.session_pool(slots=2)
        p = _prompt(60, 7, CFG)
        with pytest.raises(ValueError, match="must be positive"):
            pool.submit(p, 0)
        with pytest.raises(ValueError, match="must be positive"):
            pool.submit(p, -3)
        assert len(pool.table) == 0

    def test_empty_prompt_rejected(self, granite):
        pool = granite.session_pool(slots=2)
        with pytest.raises(ValueError, match="empty prompt"):
            pool.submit(np.zeros((0,), np.int32), 4)
        assert len(pool.table) == 0

    def test_budget_one_is_the_prefill_token(self, granite):
        pool = granite.session_pool(slots=2)
        p = _prompt(61, 7, CFG)
        sid = pool.submit(p, 1)
        outs = pool.drain()
        np.testing.assert_array_equal(outs[sid], _solo(granite, p, 1))

    def test_overlong_request_rejected(self, granite):
        pool = granite.session_pool(slots=2)
        with pytest.raises(ValueError, match="max_len"):
            pool.submit(_prompt(62, 60, CFG), 10)

    def test_pages_reclaimed(self, granite):
        pool = granite.session_pool(slots=2)
        for i in range(4):
            pool.submit(_prompt(70 + i, 8, CFG), 2)
        pool.drain()
        assert pool.alloc.free_count() == 2       # all pages back
        assert pool.table.all_done()

    def test_engine_submit_step_drain_facade(self, granite):
        params = lm.init_params(CFG, jax.random.PRNGKey(0))
        eng = Engine(CFG, params, max_len=64)
        p = _prompt(80, 8, CFG)
        sid = eng.submit(p, 3, slots=2)
        stats = eng.step()
        assert stats["emitted"] >= 1
        outs = eng.drain()
        np.testing.assert_array_equal(outs[sid], _solo(eng, p, 3))

    def test_bad_shapes_rejected(self, granite):
        with pytest.raises(ValueError, match="multiple"):
            granite.session_pool(slots=3, n_banks=2)

    def test_drain_delivers_each_session_once(self, granite):
        """Delivered sessions are evicted — a later drain returns only
        sessions finished since the last one (bounded table memory under
        a continuous stream)."""
        pool = granite.session_pool(slots=2)
        a = pool.submit(_prompt(85, 8, CFG), 2)
        first = pool.drain()
        assert set(first) == {a}
        b = pool.submit(_prompt(86, 8, CFG), 2)
        second = pool.drain()
        assert set(second) == {b}
        assert len(pool.table) == 0


# ---------------------------------------------------------------------------
# compiled-program cache keying (regression)
# ---------------------------------------------------------------------------

class TestProgramCacheKeying:
    def test_same_name_different_shapes_do_not_collide(self, granite):
        """Two builders under one name with different static shape args
        must compile separately — colliding returned the first shape's
        program for the second shape (the pool drives varying row counts
        through one engine)."""
        calls = []

        def builder(s):
            calls.append(s)
            return lambda: s

        gen = GenConfig(max_new_tokens=4)
        a = granite._program("probe", gen, builder, 8)
        b = granite._program("probe", gen, builder, 12)
        assert (a(), b()) == (8, 12)
        assert calls == [8, 12]
        # and the cache still memoizes identical keys
        assert granite._program("probe", gen, builder, 8) is a
        assert calls == [8, 12]

    def test_genconfig_arg_keys_via_key(self, granite):
        def builder(g):
            return lambda: g.max_new_tokens

        g1, g2 = GenConfig(max_new_tokens=4), GenConfig(max_new_tokens=9)
        assert granite._program("probe2", g1, builder, g1)() == 4
        assert granite._program("probe2", g2, builder, g2)() == 9

    def test_unhashable_builder_arg_rejected(self, granite):
        with pytest.raises(TypeError, match="statically hashable"):
            granite._program("probe3", GenConfig(), lambda a: a,
                             jnp.zeros((3,)))


# ---------------------------------------------------------------------------
# paged layout: sub-page banks + page-table attention
# ---------------------------------------------------------------------------

class TestPagedLayout:
    """``page_size < max_len``: KV and token storage become fixed-size
    sub-pages addressed through per-session page lists.  The contract is
    unchanged — every drained output is byte-identical to its solo static
    generation — while capacity is bounded by tokens resident, not by
    ``slots * max_len``."""

    def test_paged_ragged_matches_solo(self, granite):
        """Ragged prompts and budgets across page boundaries: sessions
        start inside one sub-page and grow across several mid-decode
        (slack pre-grant + host top-up), on 2 banks."""
        lens = [8, 12, 10, 9, 16, 7]
        budgets = [5, 12, 3, 9, 1, 14]
        prompts = [_prompt(200 + i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]
        pool = granite.session_pool(slots=4, n_banks=2, chunk=3,
                                    page_size=8, pages_per_bank=8)
        assert pool.C == 8 and pool.total_pages == 16
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)
        assert pool.alloc.page_free_count() == 16     # no sub-page leaked
        assert pool.alloc.free_count() == 4

    def test_page_pressure_parks_and_stays_identical(self, granite):
        """An under-provisioned page file (fewer sub-pages than the live
        set wants) forces mid-flight parks; the freed pages let older
        sessions finish and the parked ones restore token-identically."""
        lens = [8, 12, 10, 9]
        budgets = [9, 12, 6, 8]
        prompts = [_prompt(210 + i, s, CFG) for i, s in enumerate(lens)]
        want = [_solo(granite, p, b) for p, b in zip(prompts, budgets)]
        pool = granite.session_pool(slots=3, n_banks=1, chunk=2,
                                    page_size=4, pages_per_bank=9)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)
        assert pool.stats()["page_stalls"] > 0        # pressure actually hit
        assert pool.alloc.page_free_count() == 9

    def test_explicit_park_restore_paged(self, granite):
        """A mid-decode preempt saves ONLY live sub-pages; the restore
        (into whatever slot/pages are free then) continues the stream."""
        pa, pb = _prompt(220, 9, CFG), _prompt(221, 12, CFG)
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2,
                                    page_size=8, pages_per_bank=10)
        sa, sb = pool.submit(pa, 12), pool.submit(pb, 8)
        for _ in range(3):
            pool.step()
        sess = pool.table.get(sa)
        pool.park(sa)
        st = sess.parked
        assert st.n_pages == -(-st.row_len // 8)      # live pages only
        outs = pool.drain()
        np.testing.assert_array_equal(outs[sa], _solo(granite, pa, 12))
        np.testing.assert_array_equal(outs[sb], _solo(granite, pb, 8))
        assert pool.stats()["restores"] == 1

    def test_paged_pallas_banks_match_reference(self, granite):
        """Sub-page movement through the scalar-prefetch DMA kernels
        (gather logical rows -> fused commit -> scatter dirty pages)
        drains identical tokens to the reference jnp realization."""
        prompts = [_prompt(230 + i, 9, CFG) for i in range(4)]
        ref = granite.session_pool(slots=2, chunk=3, page_size=8,
                                   pages_per_bank=8)
        pal = granite.session_pool(slots=2, chunk=3, page_size=8,
                                   pages_per_bank=8,
                                   bank_backend="pallas",
                                   bank_interpret=True)
        for p in prompts:
            ref.submit(p, 7)
            pal.submit(p, 7)
        r, q = ref.drain(), pal.drain()
        for sid in r:
            np.testing.assert_array_equal(r[sid], q[sid])

    def test_hybrid_arch_paged_matches_solo(self, hybrid):
        """Only global-attn leaves page; rings and recurrent state stay
        per-slot and ride through park/grow untouched."""
        lens, budgets = [10, 14, 10], [6, 3, 8]
        prompts = [_prompt(240 + i, s, HYB) for i, s in enumerate(lens)]
        want = [_solo(hybrid, p, b) for p, b in zip(prompts, budgets)]
        pool = hybrid.session_pool(slots=2, page_size=8, pages_per_bank=10)
        sids = [pool.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = pool.drain()
        for sid, w in zip(sids, want):
            np.testing.assert_array_equal(outs[sid], w)

    def test_degenerate_page_size_is_whole_row_layout(self, granite):
        """Defaults (``page_size=None``) give pg = max_len, C = 1: one
        sub-page per session, the exact pre-paging layout."""
        pool = granite.session_pool(slots=2)
        assert pool.page_size == pool.max_len and pool.C == 1
        assert pool.pages_per_bank == 2                # rows_per_bank * C
        assert pool.total_pages == pool.slots          # one page per slot
        sid = pool.submit(_prompt(250, 8, CFG), 3)
        pool.step()
        sess = pool.table.get(sid)
        assert pool.alloc.pages(sess.slot) == [sess.slot]  # 1:1 with slot

    def test_bad_page_geometry_rejected(self, granite):
        with pytest.raises(ValueError, match="divisor"):
            granite.session_pool(slots=2, page_size=7)     # 64 % 7 != 0
        with pytest.raises(ValueError, match="divisor"):
            granite.session_pool(slots=2, page_size=0)
        with pytest.raises(ValueError, match="pages_per_bank"):
            granite.session_pool(slots=2, page_size=8, pages_per_bank=0)

    def test_submit_rejects_requests_beyond_bank_capacity(self, granite):
        """Regression: a request whose worst-case page count exceeds one
        bank's page file must be rejected at submit — it could never be
        seated, and previously nothing checked (satellite: no silent
        overflow/truncation)."""
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2,
                                    page_size=8, pages_per_bank=3)
        with pytest.raises(ValueError, match="bank capacity"):
            pool.submit(_prompt(260, 20, CFG), 10)     # needs 4 pages
        assert len(pool.table) == 0
        # the same request fits a deeper page file
        deep = granite.session_pool(slots=2, n_banks=1, chunk=2,
                                    page_size=8, pages_per_bank=5)
        sid = deep.submit(_prompt(260, 20, CFG), 10)
        outs = deep.drain()
        np.testing.assert_array_equal(
            outs[sid], _solo(granite, _prompt(260, 20, CFG), 10))

    def test_paged_chunk_is_three_pallas_launches_per_bank(self, granite):
        """The compiled paged decode chunk on a pallas bank lowers to
        exactly THREE kernel launches per bank — the sub-page gather, the
        ONE fused insert->truncate commit mega-kernel (the pre-paging
        invariant, alive on the paged path), and the dirty-page scatter —
        regardless of chunk size or session count."""
        from repro.cpm.program import count_pallas_calls
        pool = granite.session_pool(slots=2, n_banks=1, chunk=3,
                                    page_size=8, pages_per_bank=8,
                                    bank_backend="pallas",
                                    bank_interpret=True)
        for i in range(2):
            pool.submit(_prompt(270 + i, 9, CFG), 8)
        pool.step()                                   # admit + first chunk
        run = pool.engine._program(
            "pool_chunk", pool.gen, pool._build_chunk, pool.slots,
            pool.chunk, pool.n_banks, "pallas", True, pool.page_size,
            pool.pages_per_bank)
        budget = jnp.asarray([8, 8], jnp.int32)
        pt = np.full((pool.slots, pool.C), pool.total_pages, np.int32)
        for sess in pool.table.active():
            ids = pool.alloc.pages(sess.slot)
            pt[sess.slot, :len(ids)] = ids
        n = count_pallas_calls(
            run, pool.engine.params, pool.cur, pool.caches, pool.pos,
            jnp.asarray(pool.live), budget, jnp.asarray(pool._temp),
            jnp.asarray(pool._topk), jnp.asarray(pool._topp),
            [b.data for b in pool.banks], [b.lens for b in pool.banks],
            jnp.asarray(pt), pool.tok_lens, jax.random.PRNGKey(7))
        assert n == 3 * pool.n_banks
