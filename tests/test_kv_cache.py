"""Property tests for speculative-rollback KV-cache semantics.

The contract behind `kv_cache.truncate`: after writing a draft into the
cache and truncating back to the accepted length, the cache must be
*observationally* identical to one where the rejected tokens were never
written — including per-row accepted lengths at batch > 1.  Observable
means: every masked-visible slot matches, and decode attention over the
cache produces the same output.  (Rejected slots are not zeroed — the
`len` mask excludes them and later writes overwrite them in place; that
is the paper's O(1) content-movable range delete.)
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.serve import kv_cache

KVH, DH, SLOTS = 2, 4, 16


def _cache(b, rng):
    k = jnp.asarray(rng.normal(size=(b, KVH, SLOTS, DH)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, KVH, SLOTS, DH)), jnp.float32)
    return k, v


class TestTruncateRollback:
    @given(st.integers(0, 100), st.integers(2, 4), st.integers(1, 5))
    @settings(max_examples=20, deadline=None)
    def test_truncate_equals_never_written(self, seed, b, draft_len):
        """Write a draft at slots len0..len0+T-1 per row, accept a random
        per-row prefix, truncate — attention output must equal a cache that
        only ever saw the accepted tokens."""
        rng = np.random.default_rng(seed)
        len0 = jnp.asarray(rng.integers(1, SLOTS - draft_len, size=b),
                           jnp.int32)
        acc = jnp.asarray(rng.integers(0, draft_len + 1, size=b), jnp.int32)
        k, v = _cache(b, rng)
        draft_k = jnp.asarray(rng.normal(size=(b, KVH, draft_len, DH)),
                              jnp.float32)
        draft_v = jnp.asarray(rng.normal(size=(b, KVH, draft_len, DH)),
                              jnp.float32)

        def write(k, v, count):
            """Write `count[b]` draft entries at per-row slots."""
            rows = jnp.arange(b)[:, None]
            t = jnp.arange(draft_len)[None]
            idx = jnp.where(t < count[:, None], len0[:, None] + t, SLOTS)
            kk = k.at[rows, :, idx].set(
                jnp.moveaxis(draft_k, 2, 1), mode="drop")
            vv = v.at[rows, :, idx].set(
                jnp.moveaxis(draft_v, 2, 1), mode="drop")
            return kk, vv

        # full draft written, then rolled back to len0 + acc
        full_k, full_v = write(k, v, jnp.full((b,), draft_len, jnp.int32))
        tree = {"attn": {"k": full_k, "v": full_v, "len": len0 + draft_len}}
        tree = kv_cache.truncate(tree, len0 + acc)
        new_len = tree["attn"]["len"]
        np.testing.assert_array_equal(np.asarray(new_len),
                                      np.asarray(len0 + acc))
        # oracle: only the accepted tokens were ever written
        okk, okv = write(k, v, acc)

        # 1) every visible slot identical
        vis = jnp.arange(SLOTS)[None] < new_len[:, None]        # (B, S)
        m = vis[:, None, :, None]
        np.testing.assert_array_equal(
            np.where(np.asarray(m), np.asarray(tree["attn"]["k"]), 0.0),
            np.where(np.asarray(m), np.asarray(okk), 0.0))
        # 2) decode attention over the cache identical
        q = jnp.asarray(rng.normal(size=(b, KVH * 2, 1, DH)), jnp.float32)
        out_t = ref.decode_attention_ref(q, tree["attn"]["k"],
                                         tree["attn"]["v"], new_len)
        out_o = ref.decode_attention_ref(q, okk, okv, new_len)
        np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_o),
                                   rtol=0, atol=0)

    @given(st.integers(0, 50), st.integers(2, 4))
    @settings(max_examples=15, deadline=None)
    def test_rejected_slots_overwritten_by_later_writes(self, seed, b):
        """After truncation, the next decode writes land exactly on the
        stale slots, so the rejected draft can never be observed later."""
        rng = np.random.default_rng(seed)
        len0 = jnp.asarray(rng.integers(1, SLOTS - 3, size=b), jnp.int32)
        k, v = _cache(b, rng)
        stale = jnp.asarray(rng.normal(size=(b, KVH, DH)), jnp.float32)
        fresh = jnp.asarray(rng.normal(size=(b, KVH, DH)), jnp.float32)
        rows = jnp.arange(b)
        # stale write at per-row slot len0 (a rejected draft token), then a
        # committed write at the same per-row position
        k1 = k.at[rows, :, len0].set(stale)
        k2 = k1.at[rows, :, len0].set(fresh)
        np.testing.assert_array_equal(
            np.asarray(k2[rows, :, len0]), np.asarray(fresh))

    def test_truncate_scalar_and_vector_agree(self):
        tree = {"attn": {"k": jnp.zeros((3, KVH, SLOTS, DH)),
                         "v": jnp.zeros((3, KVH, SLOTS, DH)),
                         "len": jnp.full((3,), 9, jnp.int32)}}
        a = kv_cache.truncate(tree, 5)
        bb = kv_cache.truncate(tree, jnp.full((3,), 5, jnp.int32))
        np.testing.assert_array_equal(np.asarray(a["attn"]["len"]),
                                      np.asarray(bb["attn"]["len"]))

    def test_truncate_preserves_cross_kv(self):
        """Cross-attention caches hold encoder content: their length is the
        encoder sequence, never a decoder position — rollback must not
        clamp them."""
        tree = {"attn": {"k": jnp.zeros((2, KVH, SLOTS, DH)),
                         "v": jnp.zeros((2, KVH, SLOTS, DH)),
                         "len": jnp.full((2,), 10, jnp.int32)},
                "cross_kv": {"k": jnp.zeros((2, KVH, 50, DH)),
                             "v": jnp.zeros((2, KVH, 50, DH)),
                             "len": jnp.full((2,), 50, jnp.int32)}}
        out = kv_cache.truncate(tree, jnp.asarray([5, 7], jnp.int32))
        np.testing.assert_array_equal(np.asarray(out["attn"]["len"]), [5, 7])
        np.testing.assert_array_equal(np.asarray(out["cross_kv"]["len"]),
                                      [50, 50])

    def test_truncate_rep_stacked_lens(self):
        """Block caches stack a rep axis in front: (R, B) lens must clamp
        against (B,) per-row targets by broadcast."""
        tree = {"attn": {"k": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                         "v": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                         "len": jnp.full((2, 3), 10, jnp.int32)}}
        out = kv_cache.truncate(tree, jnp.asarray([4, 10, 7], jnp.int32))
        np.testing.assert_array_equal(np.asarray(out["attn"]["len"]),
                                      [[4, 10, 7], [4, 10, 7]])


class TestBroadcastLens:
    def test_scalar_and_stacked(self):
        tree = {"blocks": [{"attn": {"k": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                                     "v": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                                     "len": jnp.full((2,), 6, jnp.int32)}}],
                "tail": [{"attn": {"k": jnp.zeros((3, KVH, SLOTS, DH)),
                                   "v": jnp.zeros((3, KVH, SLOTS, DH)),
                                   "len": jnp.asarray(6, jnp.int32)}}]}
        out = kv_cache.broadcast_lens(tree, 3)
        assert out["blocks"][0]["attn"]["len"].shape == (2, 3)
        assert out["tail"][0]["attn"]["len"].shape == (3,)
        np.testing.assert_array_equal(
            np.asarray(out["tail"][0]["attn"]["len"]), [6, 6, 6])
        # K/V untouched
        assert out["tail"][0]["attn"]["k"].shape == (3, KVH, SLOTS, DH)

    def test_idempotent(self):
        """PR-3 regression: a second call must not stack another batch axis
        onto every len leaf (scalar -> (B,) -> (B, B))."""
        tree = {"attn": {"k": jnp.zeros((3, KVH, SLOTS, DH)),
                         "v": jnp.zeros((3, KVH, SLOTS, DH)),
                         "len": jnp.asarray(6, jnp.int32)}}
        once = kv_cache.broadcast_lens(tree, 3)
        assert once["attn"]["len"].shape == (3,)
        twice = kv_cache.broadcast_lens(once, 3)
        assert twice["attn"]["len"].shape == (3,)
        np.testing.assert_array_equal(np.asarray(twice["attn"]["len"]),
                                      np.asarray(once["attn"]["len"]))
        # per-row divergence survives the redundant call untouched
        diverged = kv_cache.truncate(once, jnp.asarray([2, 6, 4], jnp.int32))
        again = kv_cache.broadcast_lens(diverged, 3)
        np.testing.assert_array_equal(np.asarray(again["attn"]["len"]),
                                      [2, 6, 4])

    def test_idempotent_rep_stacked(self):
        tree = {"attn": {"k": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                         "v": jnp.zeros((2, 3, KVH, SLOTS, DH)),
                         "len": jnp.full((2,), 6, jnp.int32)}}
        once = kv_cache.broadcast_lens(tree, 3)
        twice = kv_cache.broadcast_lens(once, 3)
        assert twice["attn"]["len"].shape == (2, 3)

    def test_rep_count_equal_to_batch_still_broadcasts(self):
        """The ambiguous case: a fresh rep-stacked (R,) leaf with R == batch
        must still get its batch axis (the sibling k leaf disambiguates) —
        granite-style rep-stacked blocks hit this whenever R == B."""
        b = 2
        tree = {"attn": {"k": jnp.zeros((b, b, KVH, SLOTS, DH)),
                         "v": jnp.zeros((b, b, KVH, SLOTS, DH)),
                         "len": jnp.full((b,), 6, jnp.int32)}}
        once = kv_cache.broadcast_lens(tree, b)
        assert once["attn"]["len"].shape == (b, b)
        twice = kv_cache.broadcast_lens(once, b)
        assert twice["attn"]["len"].shape == (b, b)

    def test_recurrent_node_uses_C_sibling(self):
        tree = {"xlstm": {"C": jnp.zeros((3, 4, 8, 8)),
                          "n": jnp.zeros((3, 4, 8)),
                          "len": jnp.asarray(5, jnp.int32)}}
        once = kv_cache.broadcast_lens(tree, 3)
        assert once["xlstm"]["len"].shape == (3,)
        twice = kv_cache.broadcast_lens(once, 3)
        assert twice["xlstm"]["len"].shape == (3,)
