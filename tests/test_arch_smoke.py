"""Per-architecture smoke tests: a REDUCED config of each assigned arch runs
one forward/loss/grad step and a prefill+decode round-trip on CPU, asserting
output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm

ARCHS = sorted(all_configs())
B, S = 2, 32


def make_batch(cfg, key, b=B, s=S):
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    if cfg.enc_dec:
        batch["src_embeds"] = jax.random.normal(ks[1], (b, s, cfg.d_model),
                                                jnp.float32) * 0.1
    if cfg.mrope_sections is not None:
        n_patch = 4
        batch["patch_embeds"] = jax.random.normal(ks[2], (b, n_patch, cfg.d_model),
                                                  jnp.float32) * 0.1
        batch["patch_pos"] = jnp.tile(jnp.arange(1, 1 + n_patch)[None], (b, 1))
        batch["pos_ids"] = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    return batch


@pytest.fixture(scope="module")
def smoke_setup():
    out = {}
    for name in ARCHS:
        cfg = all_configs()[name].smoke()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(smoke_setup, arch):
    cfg, params = smoke_setup[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    x, aux = lm.forward(params, cfg, batch, remat=False)
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_step(smoke_setup, arch):
    cfg, params = smoke_setup[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(2))

    def f(p):
        loss, metrics = lm.loss_fn(p, cfg, batch, remat=True, loss_chunk=16)
        return loss

    loss, grads = jax.value_and_grad(f)(params)
    assert np.isfinite(float(loss))
    # loss should be near ln(V) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least most grads nonzero
    nz = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nz > len(flat) * 0.7, f"only {nz}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(smoke_setup, arch):
    """Decode after prefill must match the full-sequence forward logits."""
    cfg, params = smoke_setup[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    logits_p, caches = lm.prefill(params, cfg, batch, max_len=S + 4)
    assert logits_p.shape == (B, 1, lm.padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits_p, np.float32)).all()

    # teacher-force one more token and compare against re-prefill
    nxt = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)[:, None]
    logits_d, caches = lm.decode_step(params, cfg, nxt, caches,
                                      jnp.asarray(S, jnp.int32))
    assert logits_d.shape == (B, 1, lm.padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits_d, np.float32)).all()

    if cfg.mrope_sections is None:    # re-prefill comparison for pure-token archs
        batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
        logits_p2, _ = lm.prefill(params, cfg, batch2)
        np.testing.assert_allclose(np.asarray(logits_d[:, -1], np.float32),
                                   np.asarray(logits_p2[:, -1], np.float32),
                                   atol=0.35, rtol=0.1)


def test_decode_from_zero_matches_forward():
    """Pure decode from an empty cache must track the forward pass
    (tests cache math for a dense arch end-to-end)."""
    cfg = all_configs()["granite-8b"].smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab_size)
    x, _ = lm.forward(params, cfg, {"tokens": tokens}, remat=False)
    w = params["emb"]
    full_logits = np.asarray(x @ w.T.astype(x.dtype) if cfg.tie_embeddings
                             else x @ params["unemb"].T.astype(x.dtype),
                             np.float32)
    caches = lm.init_caches(cfg, 1, max_len=8)
    outs = []
    for t in range(8):
        lg, caches = lm.decode_step(params, cfg, tokens[:, t:t + 1], caches,
                                    jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(lg[:, 0], np.float32))
    v = cfg.vocab_size
    np.testing.assert_allclose(np.stack(outs, 1)[..., :v], full_logits[..., :v],
                               atol=0.3, rtol=0.1)
