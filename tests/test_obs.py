"""repro.obs: metrics registry, span tracing, exports, cycle accounting —
and the PR-9 overhead invariants.

The invariants are the contract that makes telemetry safe to leave on:

  * all recording is host-side between compiled calls, so the serving
    stack compiles **byte-identically** with telemetry on or off — same
    compiled-program cache keys, same pallas launch counts (jaxpr-walked
    here, not assumed);
  * span recording never forces a device sync (``block_until_ready`` is
    counted during a decode chunk and must stay at zero);
  * ``REPRO_OBS=0`` nulls spans and ledger records but the metric
    *instruments* keep functioning — they ARE the accounting behind
    ``SessionPool.stats()`` / ``Gateway.stats()``, which old tests read
    unchanged.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import all_configs
from repro.cpm import cpm_array, record
from repro.models import lm
from repro.obs import cycles, export, metrics, tracing
from repro.serve import Engine, Gateway
from repro.serve.gateway.loop import TickReport

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


def _prompt(seed, s):
    return jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                              CFG.vocab_size)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_series_and_snapshot(self):
        reg = metrics.Registry()
        c = reg.register(metrics.Counter("t_reqs", "requests", ("pool",)))
        g = reg.register(metrics.Gauge("t_occ", "occupancy"))
        c.inc(pool="0")
        c.inc(2, pool="0")
        c.inc(pool="1")
        g.default.set(0.5)
        snap = reg.snapshot()
        assert snap["t_reqs"]["kind"] == "counter"
        assert snap["t_reqs"]["series"] == {'{pool="0"}': 3,
                                            '{pool="1"}': 1}
        assert snap["t_occ"]["series"] == {"": 0.5}
        json.dumps(snap)                       # snapshot is JSON-able

    def test_label_mismatch_raises(self):
        c = metrics.Counter("t_c", "", ("bank",))
        with pytest.raises(ValueError, match="labels"):
            c.labels(pool="0")
        with pytest.raises(ValueError, match="labels"):
            c.labels()

    def test_reregister_idempotent_but_type_change_raises(self):
        reg = metrics.Registry()
        a = reg.register(metrics.Counter("t_x", "", ()))
        assert reg.register(metrics.Counter("t_x", "", ())) is a
        with pytest.raises(ValueError, match="re-registered"):
            reg.register(metrics.Gauge("t_x", "", ()))
        with pytest.raises(ValueError, match="re-registered"):
            reg.register(metrics.Counter("t_x", "", ("pool",)))

    def test_histogram_buckets_cumulative(self):
        h = metrics.Histogram("t_h", "", (), buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        s = h.series()[""]
        assert s["count"] == 4 and s["sum"] == pytest.approx(6.05)
        assert s["buckets"] == {"0.1": 1, "1.0": 3, "+Inf": 4}

    def test_prometheus_text_format(self):
        reg = metrics.Registry()
        c = reg.register(metrics.Counter("t_reqs", "total requests",
                                         ("pool",)))
        c.inc(7, pool="0")
        h = reg.register(metrics.Histogram("t_lat", "latency", (),
                                           buckets=(0.5,)))
        h.observe(0.2)
        text = reg.prometheus_text()
        assert "# HELP t_reqs total requests" in text
        assert "# TYPE t_reqs counter" in text
        assert 't_reqs{pool="0"} 7' in text
        assert 't_lat_bucket{le="0.5"} 1' in text
        assert 't_lat_bucket{le="+Inf"} 1' in text
        assert "t_lat_count 1" in text

    def test_prometheus_escaping_roundtrips_parser(self):
        """Label values with backslashes, quotes and newlines must
        survive exposition — validated by parsing the rendered text back
        with the strict mini-parser, not by substring grep."""
        from repro.obs import promparse
        reg = metrics.Registry()
        c = reg.register(metrics.Counter("t_esc", 'help with "quotes"\n',
                                         ("path",)))
        hostile = 'a\\b"c\nd'
        c.inc(3, path=hostile)
        text = reg.prometheus_text()
        fams = promparse.parse(text)
        assert fams["t_esc"].series() == {(("path", hostile),): 3.0}
        assert fams["t_esc"].help.startswith("help with")

    def test_prometheus_exposition_passes_strict_parser(self, granite):
        """The whole live registry — after real serving traffic, with
        histograms and derived summary families — must satisfy the
        mini-parser's HELP/TYPE-ordering and histogram-consistency
        checks (the same gate CI runs on a /metrics scrape)."""
        from repro.obs import promparse
        gw = Gateway(granite, slots=2, chunk=2)
        gw.result(gw.submit(_prompt(60, 8), 4, deadline_steps=100))
        fams = promparse.parse(metrics.REGISTRY.prometheus_text())
        assert "repro_gateway_requests_total" in fams
        hists = [f for f in fams.values() if f.type == "histogram"]
        assert hists                         # consistency checks all ran
        for f in hists:
            assert f.series("_count")        # _sum/_count present

    def test_series_property_shim(self):
        fam = metrics.Counter("t_shim", "", ("pool",))

        class Layer:
            hits = metrics.series_property("hits")

            def __init__(self):
                self._obs_series = {"hits": fam.labels(pool="p")}

        layer = Layer()
        layer.hits += 3
        assert layer.hits == 3
        assert fam.labels(pool="p").value == 3

    def test_disabled_instruments_still_function(self, monkeypatch):
        """REPRO_OBS=0 skips registration only — the instrument still
        counts (it backs the stats() views)."""
        monkeypatch.setenv("REPRO_OBS", "0")
        c = metrics.counter("t_disabled_counter", "", ())
        c.inc(5)
        assert c.default.value == 5
        assert metrics.REGISTRY.get("t_disabled_counter") is None


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

class TestTracing:
    def test_span_nesting_wall_and_virtual_clocks(self):
        tr = tracing.Tracer()
        clock = {"v": 10}
        with tr.span("outer", vclock=lambda: clock["v"]) as sp:
            sp.args["note"] = "x"
            with tr.span("inner"):
                pass
            clock["v"] += 4
        inner, outer = tr.spans("inner")[0], tr.spans("outer")[0]
        assert inner.depth == 1 and outer.depth == 0
        assert outer.dur >= inner.dur >= 0
        assert outer.vstep == 10 and outer.vdur == 4
        assert inner.vstep is None
        assert outer.args == {"note": "x"}

    def test_instants_and_counters(self):
        tr = tracing.Tracer()
        tr.instant("grant", vstep=3, args={"pages": 2})
        tr.counter("queue_depth", 7)
        ev = tr.spans("grant")[0]
        assert ev.dur is None and ev.vstep == 3
        assert tr.spans("queue_depth")[0].cat.startswith("__counter__.")

    def test_disabled_records_nothing(self, monkeypatch):
        monkeypatch.setenv("REPRO_OBS", "0")
        tr = tracing.Tracer()
        with tr.span("s") as sp:
            sp.args["ignored"] = 1         # null handle absorbs writes
        tr.instant("i")
        tr.counter("c", 1)
        assert tr.spans() == []

    def test_thread_isolation(self):
        import threading
        tr = tracing.Tracer()
        done = threading.Event()

        def worker():
            with tr.span("w"):
                pass
            done.set()

        with tr.span("main"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        assert done.is_set()
        w, m = tr.spans("w")[0], tr.spans("main")[0]
        assert w.tid != m.tid
        assert w.depth == 0                # sibling stacks, not nested


# ---------------------------------------------------------------------------
# chrome export
# ---------------------------------------------------------------------------

class TestExport:
    def test_chrome_trace_structure_and_validation(self):
        tr = tracing.Tracer()
        with tr.span("tick", cat="gateway", vclock=lambda: 5):
            tr.instant("grant")
        tr.counter("depth", 3)
        obj = export.chrome_trace(tr)
        counts = export.validate_chrome_trace(obj)
        assert counts == {"tick": 1, "grant": 1, "depth": 1}
        evs = {e["name"]: e for e in obj["traceEvents"] if e["ph"] != "M"}
        assert evs["tick"]["ph"] == "X" and evs["tick"]["dur"] >= 0
        assert evs["tick"]["args"]["vstep"] == 5
        assert evs["grant"]["ph"] == "i"
        assert evs["depth"]["ph"] == "C"
        assert any(e["ph"] == "M" for e in obj["traceEvents"])
        json.dumps(obj)                    # serializable as-is

    def test_validation_rejects_malformed(self):
        with pytest.raises(ValueError, match="traceEvents"):
            export.validate_chrome_trace({"events": []})
        bad = {"traceEvents": [{"ph": "X", "name": "a", "pid": 1,
                                "ts": 0.0, "dur": -1.0}]}
        with pytest.raises(ValueError, match="negative"):
            export.validate_chrome_trace(bad)
        with pytest.raises(ValueError, match="phase"):
            export.validate_chrome_trace(
                {"traceEvents": [{"ph": "?", "name": "a", "pid": 1}]})

    def test_write_trace_roundtrip(self, tmp_path):
        tr = tracing.Tracer()
        with tr.span("s"):
            pass
        path = tmp_path / "trace.json"
        export.write_trace(str(path), tr)
        assert export.validate_chrome_trace(
            json.loads(path.read_text())) == {"s": 1}

    def test_write_metrics_formats(self, tmp_path):
        metrics.counter("t_wm", "help text", ()).inc(2)
        prom = tmp_path / "m.prom"
        export.write_metrics(str(prom))
        assert "t_wm 2" in prom.read_text()
        j = tmp_path / "m.json"
        export.write_metrics(str(j), fmt="json")
        assert json.loads(j.read_text())["t_wm"]["series"][""] == 2


# ---------------------------------------------------------------------------
# cycle accounting
# ---------------------------------------------------------------------------

class TestCycles:
    def test_audit_zero_drift_across_families(self):
        """The op-table budgets equal the jaxpr-measured scan trips for
        every audited family — the live restatement of the PR-3/4
        model-vs-measured equality."""
        dev = cpm_array(jnp.arange(64), 48, backend="reference")
        with record() as prog:
            d2 = dev.insert(3, jnp.array([7, 8]))
            d2 = d2.truncate(48)
            d2.compare(9, "lt")
            d2.substring_match(jnp.array([7, 8]))
            d2.count(9, "lt")          # derived: +1 drain, not a scan trip
            d2.super_sum()
        led = cycles.CycleLedger()
        rows = cycles.audit(prog, dev, ledger=led)
        assert [r["drift"] for r in rows] == [0] * len(rows)
        sub = next(r for r in rows if r["op"] == "substring_match")
        assert sub["measured_trips"] == sub["predicted_scan"] == 2
        sup = next(r for r in rows if r["op"] == "super_sum")
        assert sup["measured_trips"] == sup["predicted_scan"] > 0
        table = led.drift_table()
        assert all(r["drift"] == 0 for r in table)
        assert {r["family"] for r in table} >= {"move", "compare",
                                                "search", "compute"}
        led.format_drift_table()           # renders without error

    def test_steps_report_feeds_ledger(self):
        ledger_before = {r["family"]: r["predicted"]
                         for r in cycles.LEDGER.drift_table()}
        dev = cpm_array(jnp.arange(32), 24, backend="reference")
        with record() as prog:
            dev.substring_match(jnp.array([1, 2, 3]))
        rep = prog.steps_report(32)
        assert rep["total"] == 3
        after = {r["family"]: r["predicted"]
                 for r in cycles.LEDGER.drift_table()}
        assert after["search"] == ledger_before.get("search", 0) + 3

    def test_steps_report_disabled_skips_ledger(self, monkeypatch):
        monkeypatch.setenv("REPRO_OBS", "0")
        before = {r["family"]: r["predicted"]
                  for r in cycles.LEDGER.drift_table()}
        dev = cpm_array(jnp.arange(32), 24, backend="reference")
        with record() as prog:
            dev.substring_match(jnp.array([1, 2]))
        prog.steps_report(32)
        after = {r["family"]: r["predicted"]
                 for r in cycles.LEDGER.drift_table()}
        assert after == before

    def test_audit_refuses_inside_trace(self):
        dev = cpm_array(jnp.arange(16), 16, backend="reference")
        with record() as prog:
            dev.compare(3, "lt")

        def traced(x):
            cycles.audit(prog, dev)
            return x

        with pytest.raises(RuntimeError, match="active jax trace"):
            jax.make_jaxpr(traced)(jnp.zeros(()))


# ---------------------------------------------------------------------------
# overhead invariants over the serving stack
# ---------------------------------------------------------------------------

def _chunk_launches(pool):
    """Pallas launch count of a freshly built decode chunk (bypasses the
    compiled-program cache so each call re-lowers under the current
    REPRO_OBS)."""
    from repro.cpm.program import count_pallas_calls
    run = pool._build_chunk(pool.slots, pool.chunk, pool.n_banks,
                            "pallas", True, pool.page_size,
                            pool.pages_per_bank)
    pt = np.full((pool.slots, pool.C), pool.total_pages, np.int32)
    return count_pallas_calls(
        run, pool.engine.params, pool.cur, pool.caches, pool.pos,
        jnp.asarray(pool.live), jnp.zeros((pool.slots,), jnp.int32),
        jnp.asarray(pool._temp), jnp.asarray(pool._topk),
        jnp.asarray(pool._topp), [b.data for b in pool.banks],
        [b.lens for b in pool.banks], jnp.asarray(pt), pool.tok_lens,
        jax.random.PRNGKey(7))


class TestOverheadInvariants:
    def test_chunk_launch_count_identical_obs_on_off(self, granite,
                                                     monkeypatch):
        """Telemetry can never change what compiles: the decode chunk
        lowers to the same pallas launch count with REPRO_OBS on or off
        (jaxpr-walked, the PR-6 trace-safety rule made enforceable)."""
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2,
                                    page_size=8, pages_per_bank=8,
                                    bank_backend="pallas",
                                    bank_interpret=True)
        monkeypatch.setenv("REPRO_OBS", "1")
        n_on = _chunk_launches(pool)
        monkeypatch.setenv("REPRO_OBS", "0")
        n_off = _chunk_launches(pool)
        assert n_on == n_off == 3 * pool.n_banks

    def test_program_cache_keys_identical_obs_on_off(self, granite,
                                                     monkeypatch):
        """The compiled-program cache is keyed identically with telemetry
        on or off — REPRO_OBS is not (and must never become) a compile
        discriminator."""
        def run_workload():
            pool = granite.session_pool(slots=2, n_banks=1, chunk=2)
            for i in range(2):
                pool.submit(_prompt(500 + i, 8), 4)
            pool.drain()
            return {k for k in granite._programs if k[0].startswith("pool")}

        monkeypatch.setenv("REPRO_OBS", "1")
        for k in list(granite._programs):
            if k[0].startswith("pool"):
                del granite._programs[k]
        keys_on = run_workload()
        monkeypatch.setenv("REPRO_OBS", "0")
        for k in list(granite._programs):
            if k[0].startswith("pool"):
                del granite._programs[k]
        keys_off = run_workload()
        assert keys_on == keys_off and keys_on

    def test_no_device_sync_inside_chunk(self, granite, monkeypatch):
        """Span recording must not force a device sync: zero
        block_until_ready calls during the traced decode chunk."""
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2)
        pool.submit(_prompt(600, 8), 6)
        pool.step()                        # admission + first chunk, warm
        syncs = {"n": 0}
        real = jax.block_until_ready

        def counting(x):
            syncs["n"] += 1
            return real(x)

        monkeypatch.setattr(jax, "block_until_ready", counting)
        pool._decode_chunk()
        assert syncs["n"] == 0
        assert tracing.TRACER.spans("pool.decode_chunk")

    def test_disabled_pool_keeps_stats_but_records_no_spans(
            self, granite, monkeypatch):
        monkeypatch.setenv("REPRO_OBS", "0")
        tracing.TRACER.clear()
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2)
        pool.submit(_prompt(610, 8), 4)
        pool.drain()
        st = pool.stats()                  # thin views keep working
        assert st["prefill_launches"] == 1 and st["emitted"] == 4
        assert tracing.TRACER.spans() == []


# ---------------------------------------------------------------------------
# serving-layer integration
# ---------------------------------------------------------------------------

class TestServingIntegration:
    def test_tick_report_schema_and_dict_fallback(self, granite):
        gw = Gateway(granite, slots=2, chunk=2)
        gw.submit(_prompt(700, 8), 4)
        rep = gw.tick()
        assert isinstance(rep, TickReport)
        assert rep.tick == 0 and rep.step == gw.pool.decode_steps
        assert rep.admitted == 1 and rep.restored == 0
        assert rep.emitted >= 1 and rep.chunk_wall_s >= 0.0
        assert rep.wall_s >= rep.chunk_wall_s
        assert rep["waiting"] == rep.waiting          # field access
        assert rep["preemptions"] == 0                # stats fallback
        assert rep.get("no_such_key", 42) == 42
        assert rep.asdict()["stats"]["prefill_launches"] == 1
        total_emitted = rep.emitted
        while gw.loop.pending():
            total_emitted += gw.tick().emitted
        assert total_emitted == gw.pool.total_emitted

    def test_pool_stats_equal_registry_series(self, granite):
        """stats() is a thin view: the registry series for this pool's
        label hold the very same numbers."""
        pool = granite.session_pool(slots=2, n_banks=1, chunk=2)
        for i in range(3):
            pool.submit(_prompt(710 + i, 8), 4)
        pool.drain()
        st = pool.stats()
        for stat_key, metric_name in [
                ("prefill_launches", "repro_pool_prefill_launches_total"),
                ("admit_batches", "repro_pool_admit_batches_total"),
                ("decode_steps", "repro_pool_decode_steps_total"),
                ("emitted", "repro_pool_emitted_total"),
                ("pages_free", "repro_pool_pages_free")]:
            fam = metrics.REGISTRY.get(metric_name)
            assert fam is not None, metric_name
            assert fam.labels(pool=pool._pool_label).value == st[stat_key]

    def test_gateway_spans_cover_every_layer(self, granite):
        tracing.TRACER.clear()
        gw = Gateway(granite, slots=2, chunk=2)
        for i in range(3):                 # oversubscribe: forces parking
            gw.submit(_prompt(720 + i, 8), 6)
        gw.tick()                          # admit the first window
        gw.pool.park(gw.request(0).sid)    # exercise park/restore spans
        while gw.loop.pending():
            gw.tick()
        counts = export.validate_chrome_trace(export.chrome_trace())
        for name in ("gateway.tick", "pool.admission", "pool.prefill",
                     "pool.decode_chunk", "pool.park", "pool.restore"):
            assert counts.get(name, 0) >= 1, (name, sorted(counts))

    def test_obs_package_exports(self):
        assert obs.enabled() in (True, False)
        assert callable(obs.span) and callable(obs.audit)
        assert obs.REGISTRY is metrics.REGISTRY
        assert obs.TRACER is tracing.TRACER
