"""`repro.obs.live` / `slo` / `promparse` — the live observability plane.

Contracts:

  * **bounded ring** — over a 10k-span run the ring never exceeds its
    capacity, counts every drop, and its chunked streaming export
    concatenates to exactly the one-shot ``chrome_trace`` JSON (and
    passes ``validate_chrome_trace``) — O(capacity) memory for a server
    that stays up indefinitely;
  * **quantiles** — histogram p50/p90/p99 interpolate within buckets,
    clamp at the top finite edge, and surface as a Prometheus ``summary``
    family the strict mini-parser accepts;
  * **burn-rate alerting** — the multi-window rule fires on an injected
    deadline-miss burst (fast AND slow both over threshold), honors
    cooldown and min-events, and the flight-recorder dump it triggers
    round-trips through the repo's own validators;
  * **registry hygiene** — ``Registry.reset()`` zeroes values while
    keeping live series references valid, and the autouse conftest
    fixture pins cross-module isolation.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.obs import export, live, metrics, promparse, slo, tracing
from repro.serve import Engine, Gateway, GenConfig

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


def _prompt(seed, s):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                                         CFG.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# the bounded ring + streaming export
# ---------------------------------------------------------------------------

class TestTraceRing:
    def test_bounded_over_10k_spans_and_chunked_export_identity(self):
        """The acceptance run: >=10k spans through a small ring — memory
        stays at capacity, drops are counted, and the chunked export is
        byte-identical to the one-shot render and still validates."""
        t = tracing.Tracer()
        ring = live.TraceRing(capacity=512).attach(t)
        n = 10_000
        for i in range(n):
            with t.span("work", args={"i": i}):
                pass
            if i % 100 == 0:
                t.instant("mark", vstep=i)
        stats = ring.stats()
        assert len(ring) == 512 and stats["len"] == 512
        assert stats["total"] == n + n // 100
        assert stats["dropped"] == stats["total"] - 512
        streamed = "".join(export.iter_trace_chunks(ring))
        assert streamed == json.dumps(
            export.chrome_trace(ring), indent=1)
        trace = json.loads(streamed)
        export.validate_chrome_trace(trace)
        # 512 data events + metadata records
        data = [e for e in trace["traceEvents"] if e["ph"] in "XiC"]
        assert len(data) == 512
        ring.detach()
        with t.span("after-detach"):
            pass
        assert ring.stats()["total"] == stats["total"]  # sink removed

    def test_write_trace_stream_file(self, tmp_path):
        t = tracing.Tracer()
        ring = live.TraceRing(capacity=64).attach(t)
        for i in range(100):
            with t.span("s", args={"i": i}):
                pass
        path = tmp_path / "stream.json"
        n = export.write_trace_stream(path, ring)
        assert n == 64
        export.validate_chrome_trace(json.loads(path.read_text()))

    def test_attach_twice_raises_and_capacity_validates(self):
        t = tracing.Tracer()
        ring = live.TraceRing(capacity=4).attach(t)
        with pytest.raises(RuntimeError, match="attached"):
            ring.attach(t)
        ring.detach()
        ring.attach(t)                      # re-attach after detach is fine
        ring.detach()
        with pytest.raises(ValueError, match="capacity"):
            live.TraceRing(capacity=0)

    def test_last_n_returns_newest(self):
        t = tracing.Tracer()
        ring = live.TraceRing(capacity=8).attach(t)
        for i in range(20):
            t.instant("e", args={"i": i})
        assert [e.args["i"] for e in ring.last(3)] == [17, 18, 19]
        assert len(ring.last(100)) == 8
        ring.detach()

    def test_tracer_set_limit_bounds_global_buffer(self):
        t = tracing.Tracer()
        for i in range(100):
            t.instant("e", args={"i": i})
        t.set_limit(10)
        assert len(t.spans()) == 10
        assert t.spans()[-1].args["i"] == 99        # newest kept
        t.set_limit(None)
        for i in range(20):
            t.instant("e2")
        assert len(t.spans()) == 30                 # unbounded again


# ---------------------------------------------------------------------------
# histogram quantiles
# ---------------------------------------------------------------------------

class TestQuantiles:
    def test_interpolation_and_top_edge_clamp(self):
        h = metrics.Histogram("t_q_lat", "", (),
                              buckets=(1.0, 2.0, 4.0, 8.0))
        s = h.default
        for v in [0.5] * 50 + [3.0] * 40 + [100.0] * 10:
            s.observe(v)
        # p50 inside (0,1]: rank 50 of 50 in-bucket observations
        assert s.quantile(0.5) == pytest.approx(1.0)
        # p90: rank 90 lands exactly at the (2,4] bucket's top
        assert s.quantile(0.9) == pytest.approx(4.0)
        # p99 is in the +Inf bucket: clamps to the top finite edge
        assert s.quantile(0.99) == pytest.approx(8.0)
        assert s.quantile(0.0) == pytest.approx(0.0)
        with pytest.raises(ValueError):
            s.quantile(1.5)

    def test_empty_series_has_no_quantiles(self):
        h = metrics.Histogram("t_q_empty", "", ())
        assert h.default.quantile(0.5) is None
        assert h.series()[""]["quantiles"] == {"p50": None, "p90": None,
                                               "p99": None}

    def test_summary_family_in_exposition_parses(self):
        reg = metrics.Registry()
        h = reg.register(metrics.Histogram("t_q_sum", "latency", ("k",),
                                           buckets=(1.0, 10.0)))
        for v in (0.5, 2.0, 20.0):
            h.labels(k="a").observe(v)
        fams = promparse.parse(reg.prometheus_text())
        assert fams["t_q_sum"].type == "histogram"
        summ = fams["t_q_sum_summary"]
        assert summ.type == "summary"
        qs = {lbl: val for lbl, val in summ.series().items()}
        assert len(qs) == 3                  # p50/p90/p99 for k="a"
        assert summ.series("_count")[(("k", "a"),)] == 3


# ---------------------------------------------------------------------------
# strict exposition parsing (the CI gate's validator)
# ---------------------------------------------------------------------------

class TestPromParse:
    def test_rejects_type_before_help(self):
        with pytest.raises(ValueError, match="without preceding HELP"):
            promparse.parse("# TYPE x counter\nx 1\n")

    def test_rejects_interleaved_families(self):
        text = ("# HELP a a\n# TYPE a counter\na 1\n"
                "# HELP b b\n# TYPE b counter\nb 1\na 2\n")
        with pytest.raises(ValueError, match="block ended"):
            promparse.parse(text)

    def test_rejects_undeclared_sample(self):
        with pytest.raises(ValueError, match="preceding"):
            promparse.parse("orphan 1\n")

    def test_rejects_noncumulative_histogram(self):
        text = ("# HELP h h\n# TYPE h histogram\n"
                'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\n'
                "h_sum 1\nh_count 3\n")
        with pytest.raises(ValueError, match="cumulative"):
            promparse.parse(text)

    def test_rejects_inf_bucket_count_mismatch(self):
        text = ("# HELP h h\n# TYPE h histogram\n"
                'h_bucket{le="1"} 1\nh_bucket{le="+Inf"} 3\n'
                "h_sum 1\nh_count 4\n")
        with pytest.raises(ValueError, match="_count"):
            promparse.parse(text)

    def test_unescapes_label_values(self):
        text = ('# HELP c c\n# TYPE c counter\n'
                'c{p="a\\\\b\\"q\\nr"} 1\n')
        fam = promparse.parse(text)["c"]
        assert fam.series() == {(("p", 'a\\b"q\nr'),): 1.0}


# ---------------------------------------------------------------------------
# burn-rate monitor + flight recorder
# ---------------------------------------------------------------------------

class TestSloMonitor:
    def _monitor(self, **kw):
        kw.setdefault("objective", 0.9)
        kw.setdefault("fast", slo.BurnWindow(steps=16, threshold=5.0))
        kw.setdefault("slow", slo.BurnWindow(steps=64, threshold=2.0))
        kw.setdefault("name", f"t{len(metrics.snapshot())}")
        return slo.SloMonitor(**kw)

    def test_all_met_never_alerts(self):
        m = self._monitor()
        for step in range(0, 200, 2):
            assert m.record(True, step) is None
        assert m.alerts == [] and m.attainment() == 1.0

    def test_miss_burst_fires_multi_window_alert(self):
        """The acceptance scenario: healthy traffic, then an injected
        deadline-miss burst — the fast window catches it, the slow
        window confirms it, one alert fires."""
        m = self._monitor()
        step = 0
        for _ in range(40):                  # healthy history
            m.record(True, step)
            step += 1
        alerts = []
        for _ in range(12):                  # the burst: all misses
            a = m.record(False, step)
            if a:
                alerts.append(a)
            step += 1
        assert len(alerts) == 1              # cooldown holds it to one
        a = alerts[0]
        assert a["fast"]["burn"] > 5.0 and a["slow"]["burn"] > 2.0
        assert m.state()["alerts"] == 1
        assert m.state()["attainment_slow"] < 1.0

    def test_min_events_guard(self):
        m = self._monitor(min_events=8)
        for i in range(4):                   # 4 misses < min_events
            assert m.record(False, i) is None
        assert m.alerts == []

    def test_burn_rate_math(self):
        m = self._monitor()                  # budget = 0.1
        for i in range(8):
            m.record(i % 2 == 0, i)         # 50% miss rate
        assert m.burn_rate(7, m.fast) == pytest.approx(5.0)

    def test_cooldown_then_refire(self):
        m = self._monitor(cooldown_steps=10)
        step = 0
        fired = 0
        for _ in range(40):
            if m.record(False, step):
                fired += 1
            step += 1
        # refires once per cooldown window while the burn persists
        assert fired >= 2
        gap = m.alerts[1]["step"] - m.alerts[0]["step"]
        assert gap >= 10

    def test_gateway_feeds_monitor(self, granite):
        m = self._monitor(fast=slo.BurnWindow(steps=8, threshold=1.0),
                          slow=slo.BurnWindow(steps=32, threshold=0.5),
                          min_events=1)
        gw = Gateway(granite, slots=2, chunk=2,
                     gen=GenConfig(max_new_tokens=4), slo_monitor=m)
        gw.result(gw.submit(_prompt(30, 6), 4, deadline_steps=100))  # met
        gw.result(gw.submit(_prompt(31, 6), 4, deadline_steps=0))    # miss
        assert m.recorded == 2
        assert m.alerts                      # the miss trips the tiny bars


class TestFlightRecorder:
    def test_dump_roundtrips_validators(self, granite, tmp_path):
        """A dump must be post-mortem-grade: its trace passes
        validate_chrome_trace, its exposition passes promparse, and its
        allocator state is consistent with the pool."""
        t = tracing.Tracer()
        ring = live.TraceRing(capacity=32).attach(t)
        for i in range(50):
            with t.span("tick", args={"i": i}):
                pass
        gw = Gateway(granite, slots=2, chunk=2,
                     gen=GenConfig(max_new_tokens=4))
        gw.submit(_prompt(40, 6), 4)
        gw.tick()                            # leaves a live session
        rec = slo.FlightRecorder(str(tmp_path), ring=ring, pool=gw.pool,
                                 last_n=16)
        path = rec.dump("test burst", extra={"k": 1})
        assert path and os.path.exists(path)
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
        d = json.loads(open(path).read())
        assert d["reason"] == "test burst" and d["extra"] == {"k": 1}
        export.validate_chrome_trace(d["trace"])
        assert len([e for e in d["trace"]["traceEvents"]
                    if e["ph"] in "XiC"]) == 16
        promparse.parse(d["metrics_prom"])
        alloc = d["allocator"]
        assert alloc["n_slots"] == 2
        assert alloc["free_slots"] == alloc["slot_state"].count(0)
        assert alloc["free_pages"] == alloc["page_state"].count(0)
        used_pages = sum(len(v) for v in alloc["page_lists"].values())
        assert used_pages == alloc["n_pages"] - alloc["free_pages"]
        ring.detach()

    def test_max_dumps_cap(self, tmp_path):
        rec = slo.FlightRecorder(str(tmp_path), max_dumps=2)
        assert rec.dump("a") and rec.dump("b")
        assert rec.dump("c") is None
        assert len(os.listdir(tmp_path)) == 2


# ---------------------------------------------------------------------------
# registry hygiene
# ---------------------------------------------------------------------------

class TestRegistryReset:
    def test_reset_zeroes_but_keeps_series_references(self):
        """The regression the conftest fixture depends on: reset() must
        zero values in place — live series handles held by serving
        objects keep working, no stale-object orphaning."""
        reg = metrics.Registry()
        c = reg.register(metrics.Counter("t_r_c", "", ("k",)))
        h = reg.register(metrics.Histogram("t_r_h", "", ()))
        series = c.labels(k="x")
        series.inc(5)
        h.default.observe(3.0)
        reg.reset()
        assert series.value == 0
        assert h.default.count == 0 and h.default.sum == 0.0
        series.inc()                         # the SAME handle still counts
        assert reg.snapshot()["t_r_c"]["series"] == {'{k="x"}': 1}

    def test_global_reset_keeps_gateway_series_valid(self, granite):
        gw = Gateway(granite, slots=2, chunk=2,
                     gen=GenConfig(max_new_tokens=4))
        gw.result(gw.submit(_prompt(50, 6), 4, deadline_steps=100))
        assert gw.slo_met_count == 1
        metrics.REGISTRY.reset()
        assert gw.slo_met_count == 0
        gw.result(gw.submit(_prompt(51, 6), 4, deadline_steps=100))
        assert gw.slo_met_count == 1         # series_property still wired

    def test_module_isolation_fixture_is_active(self, request):
        """Pin the conftest autouse fixture that prevents cross-module
        registry/tracer leakage — removing it breaks this test."""
        assert "_obs_module_isolation" in request.fixturenames
