"""`repro.serve.http` — the wire front: SSE framing, byte-identity,
overhead invariants with the HTTP plane attached.

The wire contracts:

  * **byte-identity** — the SSE token stream carries exactly the chunks
    the in-process ``Gateway.stream`` yields: same values, same chunking,
    equal as raw bytes after concatenation;
  * **SSE framing** — the incremental decoder is correct under arbitrary
    transport splits, including mid-frame and mid-UTF-8-sequence; the
    server emits keep-alive comments during silence; a client disconnect
    mid-stream cancels the request through the gateway (pages reclaimed);
  * **invariants survive the frontend** — attaching the HTTP plane (ring
    sink, SLO monitor, flight recorder) changes NOTHING about what
    compiles: same pallas launch count per chunk, same program cache
    keys, zero device syncs from serving a request over the wire.
"""

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import lm
from repro.obs import metrics, promparse, tracing, validate_chrome_trace
from repro.serve import Engine, GenConfig, Gateway, HttpFrontend
from repro.serve import http as wire

jax.config.update("jax_platform_name", "cpu")

CFG = all_configs()["granite-8b"].smoke()


@pytest.fixture(scope="module")
def granite():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, params, max_len=64)


def _prompt(seed, s):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (s,), 0,
                                         CFG.vocab_size), np.int32)


def _detok(toks):
    # CJK page: every char is 3 UTF-8 bytes, so any byte-split test that
    # slices the wire mid-character exercises incremental decoding
    return "".join(chr(0x4E00 + t % 64) for t in toks)


async def _boot(granite, *, slots=4, chunk=2, budget=8, **fe_kw):
    gw = Gateway(granite, slots=slots, n_banks=1, chunk=chunk,
                 gen=GenConfig(max_new_tokens=budget))
    fe = HttpFrontend(gw, port=0, **fe_kw)
    await fe.start()
    await gw.start()
    return gw, fe


async def _shutdown(gw, fe):
    await gw.stop()
    await fe.stop()


# ---------------------------------------------------------------------------
# SSE framing: the decoder under hostile splits
# ---------------------------------------------------------------------------

class TestSSEDecoder:
    def test_multibyte_utf8_split_across_chunks(self):
        """Feeding one byte at a time can never mis-decode: frames are
        buffered as bytes and decoded whole."""
        text = "你好，世界 — done ✓"
        frame = wire.sse_event("tokens", {"text": text, "tokens": [1, 2]})
        dec = wire.SSEDecoder()
        frames = []
        for i in range(len(frame)):              # worst case: 1-byte chunks
            frames.extend(dec.feed(frame[i:i + 1]))
        assert len(frames) == 1
        ev, data = frames[0]
        assert ev == "tokens"
        assert json.loads(data)["text"] == text

    def test_split_mid_frame_and_coalesced_frames(self):
        a = wire.sse_event("tokens", {"tokens": [1]})
        b = wire.sse_event("done", {"rid": 0})
        blob = a + b
        cut = len(a) // 2
        dec = wire.SSEDecoder()
        frames = dec.feed(blob[:cut])
        frames += dec.feed(blob[cut:])
        assert [e for e, _ in frames] == ["tokens", "done"]

    def test_comments_and_crlf_tolerated(self):
        dec = wire.SSEDecoder()
        frames = dec.feed(b": keep-alive\n\n")
        assert frames == [] and dec.comments == ["keep-alive"]
        frames = dec.feed(b"event: done\r\ndata: {}\r\n\r\n")
        assert frames == [("done", "{}")]


# ---------------------------------------------------------------------------
# wire identity: HTTP stream == in-process stream
# ---------------------------------------------------------------------------

class TestWireIdentity:
    def test_sse_stream_byte_identical_to_inprocess(self, granite):
        async def scenario():
            gw, fe = await _boot(granite, detokenize=_detok)
            try:
                prompt = _prompt(10, 6)
                body = {"prompt": [int(t) for t in prompt],
                        "max_new_tokens": 8, "deadline_steps": 200}
                http_chunks, texts, done = [], [], None
                async for ev, data in wire.sse_events(
                        fe.host, fe.port, "/v1/generate", body):
                    d = json.loads(data)
                    if ev == "tokens":
                        http_chunks.append(d["tokens"])
                        texts.append(d["text"])
                    elif ev == "done":
                        done = d
                rid = await gw.asubmit(prompt, 8)
                local_chunks = []
                async for ch in gw.stream(rid):
                    local_chunks.append([int(t) for t in ch])
                # identical values AND identical chunking, as raw bytes
                assert np.asarray(sum(http_chunks, []), np.int32).tobytes() \
                    == np.asarray(sum(local_chunks, []), np.int32).tobytes()
                assert http_chunks == local_chunks
                assert "".join(texts) == _detok(sum(http_chunks, []))
                assert done["n_tokens"] == len(prompt) + 8
                assert done["slo_met"] is True and not done["cancelled"]
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())

    def test_nonstream_matches_stream(self, granite):
        async def scenario():
            gw, fe = await _boot(granite)
            try:
                prompt = _prompt(11, 5)
                body = {"prompt": [int(t) for t in prompt],
                        "max_new_tokens": 6, "stream": False}
                status, _, raw = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate", body)
                assert status == 200
                d = json.loads(raw)
                rid = await gw.asubmit(prompt, 6)
                expect = await gw.aresult(rid)
                # non-stream responses carry prompt + generated (the
                # sync-face contract); the stream face omits the prompt
                assert d["tokens"][-6:] == [int(t) for t in expect[-6:]]
                assert d["n_tokens"] == len(expect)
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())

    def test_per_request_gen_override_applies(self, granite):
        async def scenario():
            gw, fe = await _boot(granite)
            try:
                prompt = _prompt(12, 5)
                body = {"prompt": [int(t) for t in prompt],
                        "max_new_tokens": 4,
                        "gen": {"temperature": 0.0}, "stream": False}
                status, _, raw = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate", body)
                assert status == 200
                greedy = json.loads(raw)["tokens"][-4:]
                sid_toks = gw.result(gw.submit(
                    prompt, 4, gen=GenConfig(max_new_tokens=4,
                                             temperature=0.0)))
                assert greedy == [int(t) for t in sid_toks[-4:]]
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())


# ---------------------------------------------------------------------------
# server-side SSE behavior: keep-alives, disconnect-cancel
# ---------------------------------------------------------------------------

class TestSSEServer:
    def test_keepalive_comments_during_silence(self, granite):
        """While no tokens arrive (tick loop not yet running — the wire
        analogue of a long prefill) the stream must carry keep-alive
        comments so intermediaries don't drop the connection."""
        async def scenario():
            gw = Gateway(granite, slots=2, n_banks=1, chunk=2,
                         gen=GenConfig(max_new_tokens=4))
            fe = await HttpFrontend(gw, port=0, keepalive_s=0.05).start()
            try:
                async def late_start():
                    await asyncio.sleep(0.4)
                    await gw.start()
                starter = asyncio.ensure_future(late_start())
                dec = wire.SSEDecoder()
                events = []
                async for ev, _ in wire.sse_events(
                        fe.host, fe.port, "/v1/generate",
                        {"prompt": [int(t) for t in _prompt(13, 4)],
                         "max_new_tokens": 4}, decoder=dec):
                    events.append(ev)
                await starter
                assert events[0] == "start" and events[-1] == "done"
                assert len(dec.comments) >= 3       # ~0.4s of 0.05s beats
                assert all(c == "keep-alive" for c in dec.comments)
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())

    def test_client_disconnect_cancels_request(self, granite):
        """Closing the socket mid-stream must cancel the request through
        the gateway: the slot frees, the request grades as cancelled."""
        async def scenario():
            gw, fe = await _boot(granite, chunk=1, budget=48)
            try:
                before = metrics.snapshot().get(
                    "repro_http_disconnects_total",
                    {"series": {}})["series"].get("", 0)
                reader, writer = await asyncio.open_connection(
                    fe.host, fe.port)
                body = json.dumps({
                    "prompt": [int(t) for t in _prompt(14, 4)],
                    "max_new_tokens": 48}).encode()
                writer.write(wire._request_bytes(
                    "POST", "/v1/generate", fe.host, body))
                await writer.drain()
                await reader.readuntil(b"start")    # stream is live
                writer.close()                      # client walks away
                await writer.wait_closed()
                req = gw.request(gw._next_rid - 1)
                # generous poll: the first tick may hold the tick lock
                # through a cold compile before the cancel can land
                for _ in range(1500):
                    if req.done:
                        break
                    await asyncio.sleep(0.02)
                assert req.done and req.cancelled
                assert len(req.tokens) < len(req.prompt) + 48
                for _ in range(500):    # slot frees once the tick settles
                    if gw.pool.alloc.free_count() == gw.pool.slots:
                        break
                    await asyncio.sleep(0.02)
                assert gw.pool.alloc.free_count() == gw.pool.slots
                after = metrics.snapshot()[
                    "repro_http_disconnects_total"]["series"][""]
                assert after == before + 1
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())


# ---------------------------------------------------------------------------
# plain routes + error statuses
# ---------------------------------------------------------------------------

class TestRoutes:
    def test_healthz_stats_metrics_trace(self, granite):
        async def scenario():
            gw, fe = await _boot(granite)
            try:
                rid = await gw.asubmit(_prompt(15, 5), 4)
                await gw.aresult(rid)
                st, _, raw = await wire.request(fe.host, fe.port, "GET",
                                                "/healthz")
                assert st == 200 and json.loads(raw)["ok"] is True
                st, _, raw = await wire.request(fe.host, fe.port, "GET",
                                                "/v1/stats")
                d = json.loads(raw)
                assert st == 200
                assert d["tick"]["stats"]["prefill_launches"] >= 1
                assert d["stats"]["requests"] >= 1 and d["stats"]["completed"] >= 1
                assert d["ring"]["capacity"] == fe.ring.capacity
                assert d["slo"]["objective"] == fe.slo_monitor.objective
                st, _, raw = await wire.request(fe.host, fe.port, "GET",
                                                "/metrics")
                fams = promparse.parse(raw.decode())
                assert "repro_gateway_requests_total" in fams
                assert "repro_http_requests_total" in fams
                st, hdrs, raw = await wire.request(fe.host, fe.port, "GET",
                                                   "/debug/trace")
                assert st == 200
                assert hdrs.get("transfer-encoding") == "chunked"
                trace = json.loads(raw.decode())
                validate_chrome_trace(trace)
                assert trace["traceEvents"]
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())

    def test_error_statuses(self, granite):
        async def scenario():
            gw, fe = await _boot(granite)
            try:
                cases = [
                    ("GET", "/no/such/route", None, 404),
                    ("POST", "/metrics", None, 405),
                    ("GET", "/v1/generate", None, 405),
                    ("POST", "/v1/generate", b"not json", 400),
                    ("POST", "/v1/generate", {"prompt": "strings"}, 400),
                    ("POST", "/v1/generate",
                     {"prompt": [1, 2], "gen": {"bogus": 1}}, 400),
                    ("POST", "/v1/generate", {"prompt": []}, 400),
                ]
                for method, path, body, expect in cases:
                    st, _, raw = await wire.request(fe.host, fe.port,
                                                    method, path, body)
                    assert st == expect, (path, raw)
                    assert "error" in json.loads(raw)
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())


# ---------------------------------------------------------------------------
# overhead invariants with the HTTP plane attached
# ---------------------------------------------------------------------------

def _chunk_launches(pool):
    from repro.cpm.program import count_pallas_calls
    import jax.numpy as jnp
    run = pool._build_chunk(pool.slots, pool.chunk, pool.n_banks,
                            "pallas", True, pool.page_size,
                            pool.pages_per_bank)
    pt = np.full((pool.slots, pool.C), pool.total_pages, np.int32)
    return count_pallas_calls(
        run, pool.engine.params, pool.cur, pool.caches, pool.pos,
        jnp.asarray(pool.live), jnp.zeros((pool.slots,), jnp.int32),
        jnp.asarray(pool._temp), jnp.asarray(pool._topk),
        jnp.asarray(pool._topp), [b.data for b in pool.banks],
        [b.lens for b in pool.banks], jnp.asarray(pt), pool.tok_lens,
        jax.random.PRNGKey(7))


class TestInvariantsWithHttp:
    def test_launch_count_unchanged_with_frontend_attached(self, granite):
        """Mounting the wire front (ring sink + SLO monitor + recorder)
        must not change what compiles: still 3 pallas launches per bank
        per decode chunk, jaxpr-walked with the frontend live."""
        async def scenario():
            gw = Gateway(granite, slots=2, n_banks=1, chunk=2,
                         page_size=8, pages_per_bank=8,
                         bank_backend="pallas", bank_interpret=True,
                         gen=GenConfig(max_new_tokens=4))
            fe = await HttpFrontend(gw, port=0).start()
            await gw.start()
            try:
                st, _, _ = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate",
                    {"prompt": [int(t) for t in _prompt(16, 5)],
                     "max_new_tokens": 4, "stream": False})
                assert st == 200
                n = await asyncio.to_thread(_chunk_launches, gw.pool)
                assert n == 3 * gw.pool.n_banks
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())

    def test_program_cache_keys_identical_with_and_without_http(
            self, granite):
        """The compiled-program cache must key identically whether the
        workload arrives over the wire or in-process."""
        def clear():
            for k in list(granite._programs):
                if k[0].startswith("pool"):
                    del granite._programs[k]

        def keys():
            return {k for k in granite._programs if k[0].startswith("pool")}

        prompt = _prompt(17, 6)
        clear()
        gw = Gateway(granite, slots=2, n_banks=1, chunk=2,
                     gen=GenConfig(max_new_tokens=4))
        gw.result(gw.submit(prompt, 4))
        keys_plain = keys()

        async def over_http():
            gw2, fe = await _boot(granite, slots=2, chunk=2, budget=4)
            try:
                st, _, _ = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate",
                    {"prompt": [int(t) for t in prompt],
                     "max_new_tokens": 4, "stream": False})
                assert st == 200
            finally:
                await _shutdown(gw2, fe)

        clear()
        asyncio.run(over_http())
        assert keys() == keys_plain and keys_plain

    def test_no_device_sync_serving_over_http(self, granite, monkeypatch):
        """Serving a request over the wire adds zero block_until_ready
        calls: every handler reads host mirrors only."""
        async def scenario():
            gw, fe = await _boot(granite, slots=2, budget=4)
            try:
                # warm all compiles first so the counted run is steady-state
                st, _, _ = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate",
                    {"prompt": [int(t) for t in _prompt(18, 5)],
                     "max_new_tokens": 4, "stream": False})
                assert st == 200
                syncs = {"n": 0}
                real = jax.block_until_ready

                def counting(x):
                    syncs["n"] += 1
                    return real(x)

                monkeypatch.setattr(jax, "block_until_ready", counting)
                st, _, _ = await wire.request(
                    fe.host, fe.port, "POST", "/v1/generate",
                    {"prompt": [int(t) for t in _prompt(18, 5)],
                     "max_new_tokens": 4, "stream": False})
                monkeypatch.undo()
                assert st == 200 and syncs["n"] == 0
            finally:
                await _shutdown(gw, fe)
        asyncio.run(scenario())


# ---------------------------------------------------------------------------
# serve(http_port=) lifecycle
# ---------------------------------------------------------------------------

class TestServeMount:
    def test_serve_mounts_and_unmounts_frontend(self, granite):
        async def scenario():
            gw = Gateway(granite, slots=2, n_banks=1, chunk=2,
                         gen=GenConfig(max_new_tokens=4))
            task = asyncio.ensure_future(gw.serve(http_port=0))
            for _ in range(100):
                if gw.http is not None and gw.http.port:
                    break
                await asyncio.sleep(0.01)
            assert gw.http is not None
            port = gw.http.port
            st, _, raw = await wire.request("127.0.0.1", port, "GET",
                                            "/healthz")
            assert st == 200 and json.loads(raw)["ok"]
            assert gw.slo_monitor is gw.http.slo_monitor  # auto-wired
            gw._stopping = True
            gw._ensure_wake().set()
            await task
            with pytest.raises(OSError):
                await wire.request("127.0.0.1", port, "GET", "/healthz")
            # ring detached: the global tracer has no lingering sink
            assert gw.http.ring not in tracing.TRACER._sinks
        asyncio.run(scenario())
