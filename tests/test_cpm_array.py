"""`repro.cpm` — the unified operator surface.

Covers the PR-2 acceptance criteria: all five op families through
``CPMArray`` on the reference and pallas backends with bit-identical
results; mesh covered for section_sum/global_limit under a 2-device CPU
mesh (subprocess, so the main process keeps its single-device view);
pytree/jit/vmap compatibility with a traced ``used_len``; the canonical
match semantics with its converters; and the kernel-vs-reference tail
equivalence for the sliding-window ops.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.cpm as cpm
from repro.cpm import CPMArray, cpm_array
from repro.cpm.program import count_pallas_calls, scan_trip_count
from repro.cpm.reference import computable
from repro.kernels import cpm_kernels

jax.config.update("jax_platform_name", "cpu")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def int_data(seed, n, lo=0, hi=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), lo, hi)


def pair(data, used):
    """(reference, pallas-interpret) views of the same device state."""
    return (cpm_array(data, used, backend="reference"),
            cpm_array(data, used, backend="pallas", interpret=True))


# ---------------------------------------------------------------------------
# cross-backend differential: all five families, bit-identical
# ---------------------------------------------------------------------------

class TestBackendDifferential:
    @pytest.mark.parametrize("n,used", [(64, 50), (130, 130), (96, 17)])
    def test_activate_family(self, n, used):
        ref, pal = pair(int_data(n, n), used)
        np.testing.assert_array_equal(np.asarray(ref.activate(3, n - 2, 3)),
                                      np.asarray(pal.activate(3, n - 2, 3)))

    @pytest.mark.parametrize("n,used", [(64, 50), (130, 100)])
    def test_move_family(self, n, used):
        ref, pal = pair(int_data(n, n), used)
        for get in (lambda a: a.insert(4, jnp.array([9, 9])),
                    lambda a: a.delete(4, 2),
                    lambda a: a.shift(2, used - 1, 3),
                    lambda a: a.shift(5, used - 1, -2, fill=-1)):
            r, p = get(ref), get(pal)
            np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))
            np.testing.assert_array_equal(np.asarray(r.used_len),
                                          np.asarray(p.used_len))

    @pytest.mark.parametrize("n,used", [(64, 50), (130, 130)])
    def test_search_family(self, n, used):
        data = int_data(n, n, 0, 4)
        ref, pal = pair(data, used)
        nee = data[5:8]
        for where in ("start", "end"):
            np.testing.assert_array_equal(
                np.asarray(ref.substring_match(nee, where=where)),
                np.asarray(pal.substring_match(nee, where=where)))
        ri, rv = ref.find_all(nee, 8)
        pi, pv = pal.find_all(nee, 8)
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(pi))
        np.testing.assert_array_equal(np.asarray(rv), np.asarray(pv))

    @pytest.mark.parametrize("n,used", [(64, 50), (130, 130)])
    def test_compare_family(self, n, used):
        ref, pal = pair(int_data(n, n), used)
        for op in ("eq", "lt", "ge"):
            np.testing.assert_array_equal(np.asarray(ref.compare(3, op)),
                                          np.asarray(pal.compare(3, op)))
            np.testing.assert_array_equal(np.asarray(ref.count(3, op)),
                                          np.asarray(pal.count(3, op)))
        edges = jnp.array([0, 2, 4, 7])
        np.testing.assert_array_equal(np.asarray(ref.histogram(edges)),
                                      np.asarray(pal.histogram(edges)))

    @pytest.mark.parametrize("n,used", [(64, 50), (130, 100)])
    def test_compute_family(self, n, used):
        data = int_data(n, n)
        ref, pal = pair(data, used)
        np.testing.assert_array_equal(np.asarray(ref.section_sum()),
                                      np.asarray(pal.section_sum()))
        for mode in ("max", "min"):
            np.testing.assert_array_equal(np.asarray(ref.global_limit(mode)),
                                          np.asarray(pal.global_limit(mode)))
        np.testing.assert_array_equal(np.asarray(ref.sort().data),
                                      np.asarray(pal.sort().data))
        fref, fpal = pair(data.astype(jnp.float32), used)
        t = data[3:6].astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(fref.template_match(t)),
                                      np.asarray(fpal.template_match(t)))
        for wrap in (False, True):
            np.testing.assert_array_equal(
                np.asarray(fref.stencil((1.0, 2.0, 1.0), wrap=wrap)),
                np.asarray(fpal.stencil((1.0, 2.0, 1.0), wrap=wrap)))

    def test_float_section_sum_tolerance(self):
        """Float reductions differ by accumulation order across backends —
        the contract is tolerance, not bit-identity (ints ARE bit-exact)."""
        data = jax.random.normal(jax.random.PRNGKey(5), (4096,))
        ref, pal = pair(data, 4096)
        np.testing.assert_allclose(np.asarray(ref.section_sum()),
                                   np.asarray(pal.section_sum()), rtol=1e-5)

    def test_large_int_section_sum_exact(self):
        """Integer sums must accumulate exactly (int32, not float32) even
        when intermediates exceed the f32 mantissa (2^24)."""
        data = jax.random.randint(jax.random.PRNGKey(3), (4096,), 0, 1 << 16)
        ref, pal = pair(data, 4096)
        np.testing.assert_array_equal(np.asarray(ref.section_sum()),
                                      np.asarray(pal.section_sum()))
        assert int(ref.section_sum()) == int(np.asarray(data, np.int64).sum())

    def test_compare_promotes_float_datum(self):
        """A fractional threshold on an int array must not be truncated."""
        arr = cpm_array(jnp.array([0, 1, 2, 3], jnp.int32))
        for backend in ("reference", "pallas"):
            a = cpm_array(arr.data, backend=backend,
                          interpret=True if backend == "pallas" else None)
            np.testing.assert_array_equal(np.asarray(a.compare(2.5, "lt")),
                                          [True, True, True, False])

    def test_forced_backend_rejects_unsupported_op(self):
        arr = cpm_array(jnp.arange(8), backend="mesh")
        with pytest.raises(NotImplementedError):
            arr.sort()


# ---------------------------------------------------------------------------
# batched (R, N) reductions: one kernel launch, cross-backend bit-identity
# ---------------------------------------------------------------------------

def batched_pair(data, lens):
    return (CPMArray(data, lens, backend="reference"),
            CPMArray(data, lens, backend="pallas", interpret=True))


class TestBatchedReductions:
    """PR-3 tentpole: (R, N) layouts dispatch as ONE pallas launch and are
    bit-identical to the reference for ints (floats to tolerance)."""

    LENS = jnp.array([130, 64, 17, 0], jnp.int32)

    def _int_batch(self):
        data = jax.random.randint(jax.random.PRNGKey(7), (4, 130), 0, 1000)
        return batched_pair(data, self.LENS)

    def test_batched_section_sum_bit_identical(self):
        ref, pal = self._int_batch()
        want = [int(np.asarray(ref.data)[i, :l].sum())
                for i, l in enumerate(self.LENS)]
        np.testing.assert_array_equal(np.asarray(ref.section_sum()), want)
        np.testing.assert_array_equal(np.asarray(pal.section_sum()), want)

    @pytest.mark.parametrize("mode", ["max", "min"])
    def test_batched_global_limit_bit_identical(self, mode):
        ref, pal = self._int_batch()
        np.testing.assert_array_equal(np.asarray(ref.global_limit(mode)),
                                      np.asarray(pal.global_limit(mode)))

    def test_batched_histogram_bit_identical_and_tiled(self):
        """Histogram correct for N larger than one VMEM section: drive the
        kernel with a section far smaller than the row."""
        ref, pal = self._int_batch()
        edges = jnp.array([0, 250, 500, 1000])
        r, p = ref.histogram(edges), pal.histogram(edges)
        assert r.shape == p.shape == (4, 3)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))
        # same data through an explicitly multi-section kernel grid
        x = jnp.where(ref._live(), ref.data, edges[-1])
        tiled = cpm_kernels.histogram(x, edges, 32, interpret=True)
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(r))

    def test_batched_float_reductions_tolerance(self):
        data = jax.random.normal(jax.random.PRNGKey(8), (3, 200))
        lens = jnp.array([200, 150, 9], jnp.int32)
        ref, pal = batched_pair(data, lens)
        np.testing.assert_allclose(np.asarray(ref.section_sum()),
                                   np.asarray(pal.section_sum()), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ref.super_sum()),
                                   np.asarray(pal.super_sum()), rtol=1e-5)
        for mode in ("max", "min"):          # limits are order-free: exact
            np.testing.assert_array_equal(np.asarray(ref.global_limit(mode)),
                                          np.asarray(pal.global_limit(mode)))
            np.testing.assert_array_equal(np.asarray(ref.super_limit(mode)),
                                          np.asarray(pal.super_limit(mode)))

    @pytest.mark.parametrize("op,call", [
        ("section_sum", lambda a: a.section_sum()),
        ("global_limit", lambda a: a.global_limit("max")),
        ("histogram", lambda a: a.histogram(jnp.array([0, 500, 1000]))),
        ("super_sum", lambda a: a.super_sum()),
        ("super_limit", lambda a: a.super_limit("min")),
    ])
    def test_single_pallas_call_no_vmap_over_launch(self, op, call):
        _, pal = self._int_batch()
        assert count_pallas_calls(call, pal) == 1, \
            f"batched {op} must lower to exactly one pallas_call"

    def test_deep_batch_shape(self):
        data = jax.random.randint(jax.random.PRNGKey(9), (2, 3, 40), 0, 50)
        lens = jnp.array([[40, 12, 0], [7, 40, 33]], jnp.int32)
        ref, pal = batched_pair(data, lens)
        assert ref.section_sum().shape == pal.section_sum().shape == (2, 3)
        np.testing.assert_array_equal(np.asarray(ref.section_sum()),
                                      np.asarray(pal.section_sum()))
        np.testing.assert_array_equal(np.asarray(ref.histogram(jnp.array([0, 25, 50]))),
                                      np.asarray(pal.histogram(jnp.array([0, 25, 50]))))

    def test_batched_find_all_respects_max_out(self):
        """PR-3 satellite regression: enumerate_matches must slice the
        address axis, not the batch axis."""
        data = jnp.tile(jnp.array([[1, 2, 1, 2, 1, 2, 0, 0]]), (3, 1))
        arr = cpm_array(data, jnp.array([8, 8, 2], jnp.int32))
        idx, valid = arr.find_all(jnp.array([1, 2]), max_out=2)
        assert idx.shape == valid.shape == (3, 2)
        np.testing.assert_array_equal(np.asarray(idx), [[0, 2], [0, 2], [0, 8]])
        np.testing.assert_array_equal(np.asarray(valid),
                                      [[True, True], [True, True],
                                       [True, False]])


# ---------------------------------------------------------------------------
# §8 super ops: log-depth combine equals the two-phase result
# ---------------------------------------------------------------------------

class TestCompactBackends:
    """§4.2 compact on the pallas backend (log-depth cumsum-gather kernel):
    bit-identical to the reference argsort pack, including batched (R, N)
    rows with per-row lengths, both output data and the new ``used_len``."""

    @pytest.mark.parametrize("n,used", [(64, 50), (130, 130), (96, 17),
                                        (8, 1), (1, 1)])
    def test_1d_bit_identical(self, n, used):
        data = int_data(n, n, hi=100)
        keep = jax.random.bernoulli(jax.random.PRNGKey(n + used), 0.4, (n,))
        ref, pal = pair(data, used)
        r, p = ref.compact(keep, fill=-1), pal.compact(keep, fill=-1)
        np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))
        assert int(r.used_len) == int(p.used_len)

    @pytest.mark.parametrize("flag", [True, False])
    def test_all_or_none_kept(self, flag):
        data = int_data(3, 40)
        ref, pal = pair(data, 33)
        keep = jnp.full((40,), flag)
        r, p = ref.compact(keep, fill=9), pal.compact(keep, fill=9)
        np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))
        assert int(r.used_len) == int(p.used_len) == (33 if flag else 0)

    def test_batched_rows_bit_identical(self):
        lens = jnp.array([130, 64, 17, 0], jnp.int32)
        data = jax.random.randint(jax.random.PRNGKey(7), (4, 130), 0, 1000)
        keep = jax.random.bernoulli(jax.random.PRNGKey(8), 0.5, (4, 130))
        ref, pal = batched_pair(data, lens)
        r, p = ref.compact(keep, fill=-1), pal.compact(keep, fill=-1)
        np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))
        np.testing.assert_array_equal(np.asarray(r.used_len),
                                      np.asarray(p.used_len))
        # per-row oracle: kept values within each row's live prefix, packed
        for i, l in enumerate(np.asarray(lens)):
            want = np.asarray(data)[i, :l][np.asarray(keep)[i, :l]]
            np.testing.assert_array_equal(
                np.asarray(p.data)[i, :len(want)], want)

    def test_float_rows_bit_identical(self):
        data = jax.random.normal(jax.random.PRNGKey(9), (3, 64))
        keep = jax.random.bernoulli(jax.random.PRNGKey(10), 0.3, (3, 64))
        ref, pal = batched_pair(data, jnp.array([64, 20, 5], jnp.int32))
        r, p = ref.compact(keep, fill=0.5), pal.compact(keep, fill=0.5)
        np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))

    def test_compact_is_one_pallas_launch(self):
        arr = cpm_array(int_data(4, 128), 100, backend="pallas",
                        interpret=True)
        keep = jax.random.bernoulli(jax.random.PRNGKey(11), 0.5, (128,))
        assert count_pallas_calls(
            lambda a: a.compact(keep).data, arr) == 1


class TestSuperOps:
    @pytest.mark.parametrize("n,used", [(64, 50), (130, 130), (96, 17)])
    def test_super_equals_two_phase(self, n, used):
        data = int_data(n, n, 0, 1000)
        for backend_arr in pair(data, used):
            np.testing.assert_array_equal(
                np.asarray(backend_arr.super_sum()),
                np.asarray(backend_arr.section_sum()))
            for mode in ("max", "min"):
                np.testing.assert_array_equal(
                    np.asarray(backend_arr.super_limit(mode)),
                    np.asarray(backend_arr.global_limit(mode)))

    def test_super_cross_backend_bit_identical(self):
        ref, pal = pair(int_data(11, 130, 0, 1 << 16), 100)
        np.testing.assert_array_equal(np.asarray(ref.super_sum()),
                                      np.asarray(pal.super_sum()))
        for mode in ("max", "min"):
            np.testing.assert_array_equal(np.asarray(ref.super_limit(mode)),
                                          np.asarray(pal.super_limit(mode)))

    def test_registered_with_log_bound(self):
        for name in ("super_sum", "super_limit"):
            spec = cpm.OP_TABLE[name]
            assert spec.paper == "§8"
            assert set(spec.backends) == {"reference", "pallas", "mesh"}
            for n in (64, 1000, 4096, 1 << 20):
                steps = cpm.op_steps(name, n=n)      # bound-checked
                assert steps <= 2 * int(np.ceil(np.log2(n))) + 1
        # the √N -> log N upgrade is real at scale
        assert (cpm.op_steps("super_sum", n=1 << 20)
                < cpm.op_steps("section_sum", n=1 << 20) // 10)

    @pytest.mark.parametrize("n", [64, 1000, 4096])
    def test_reference_lowering_trip_count_matches_table(self, n):
        """The scan trip count of the lowered jaxpr IS the registered
        concurrent-step formula (phase-1 levels + phase-2 levels)."""
        arr = cpm_array(int_data(1, n), n, backend="reference")
        got = scan_trip_count(lambda a: a.super_sum(), arr)
        assert got == cpm.op_steps("super_sum", n=n)
        got = scan_trip_count(lambda a: a.super_limit(), arr)
        assert got == cpm.op_steps("super_limit", n=n)


# ---------------------------------------------------------------------------
# satellite: wrapping-tail consistency (kernel vs reference, tails included)
# ---------------------------------------------------------------------------

class TestWindowTailSemantics:
    @pytest.mark.parametrize("n,m", [(32, 4), (65, 7)])
    def test_template_kernel_matches_reference_including_tail(self, n, m):
        """Raw kernel and raw reference agree at *every* position — including
        the wrapped tail — and the canonical surface masks that tail."""
        data = jax.random.normal(jax.random.PRNGKey(0), (1, n))
        t = jax.random.normal(jax.random.PRNGKey(1), (m,))
        raw_kernel = np.asarray(cpm_kernels.template_match(data, t))[0]
        raw_ref = np.asarray(computable.template_match_1d(data[0], t))
        np.testing.assert_array_equal(raw_kernel, raw_ref)

        ref, pal = pair(data[0], n)
        for arr in (ref, pal):
            out = np.asarray(arr.template_match(t))
            assert np.all(np.isinf(out[n - m + 1:])), "tail not masked"
            assert np.all(np.isfinite(out[: n - m + 1]))

    @pytest.mark.parametrize("taps", [(1.0, 2.0, 1.0), (1.0, 1.0, 1.0, 1.0, 1.0)])
    def test_stencil_wrap_flag_consistent(self, taps):
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 33))
        for wrap in (False, True):
            got = np.asarray(cpm_kernels.stencil(x, taps, wrap=wrap))
            want = np.asarray(jax.vmap(
                lambda r: computable.stencil_1d(r, list(taps), wrap=wrap))(x))
            np.testing.assert_allclose(got, want, atol=1e-6)
        # the two conventions genuinely differ at the row ends
        a = np.asarray(cpm_kernels.stencil(x, taps, wrap=True))
        b = np.asarray(cpm_kernels.stencil(x, taps, wrap=False))
        assert not np.allclose(a[:, 0], b[:, 0])

    def test_stencil_wrap_true_is_historical_ring(self):
        """wrap=True must reproduce the historical full-buffer ring even on
        a partially-used array (no masked zeros leaking into the ring)."""
        x = jnp.arange(1.0, 9.0)
        arr = cpm_array(x, used_len=4)
        got = np.asarray(arr.stencil((1.0, 0.0, 0.0), wrap=True))
        want = np.asarray(computable.stencil_1d(x, [1.0, 0.0, 0.0]))
        np.testing.assert_array_equal(got, want)

    def test_used_len_tightens_window_validity(self):
        data = jnp.arange(16.0)
        arr = cpm_array(data, used_len=10)
        out = np.asarray(arr.template_match(jnp.array([1.0, 2, 3])))
        assert np.all(np.isinf(out[8:]))      # windows past used_len invalid
        assert np.all(np.isfinite(out[:8]))


# ---------------------------------------------------------------------------
# canonical match semantics + converters
# ---------------------------------------------------------------------------

class TestSemantics:
    def test_start_end_round_trip(self):
        hay = jnp.array(list(b"abracadabra"), jnp.int32)
        nee = jnp.array(list(b"abra"), jnp.int32)
        arr = cpm_array(hay)
        starts = arr.substring_match(nee, where="start")
        ends = arr.substring_match(nee, where="end")
        np.testing.assert_array_equal(np.where(np.asarray(starts))[0], [0, 7])
        np.testing.assert_array_equal(np.where(np.asarray(ends))[0], [3, 10])
        np.testing.assert_array_equal(
            np.asarray(cpm.ends_to_starts(ends, 4)), np.asarray(starts))
        np.testing.assert_array_equal(
            np.asarray(cpm.starts_to_ends(starts, 4)), np.asarray(ends))

    def test_match_restricted_to_used_region(self):
        hay = jnp.array(list(b"abcabcabc"), jnp.int32)
        arr = cpm_array(hay, used_len=5)       # "abcab"
        starts = arr.substring_match(jnp.array(list(b"abc"), jnp.int32))
        np.testing.assert_array_equal(np.where(np.asarray(starts))[0], [0])

    def test_window_valid(self):
        v = np.asarray(cpm.window_valid(8, 3, 6))
        np.testing.assert_array_equal(np.where(v)[0], [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# satellite: pytree / jit / vmap compatibility
# ---------------------------------------------------------------------------

class TestTransformCompat:
    def test_pytree_roundtrip_preserves_aux(self):
        arr = cpm_array(jnp.arange(8), 5, backend="pallas", interpret=True)
        leaves, tree = jax.tree_util.tree_flatten(arr)
        assert len(leaves) == 2
        back = jax.tree_util.tree_unflatten(tree, leaves)
        assert back.backend == "pallas" and back.interpret is True
        np.testing.assert_array_equal(np.asarray(back.data),
                                      np.asarray(arr.data))

    def test_jit_no_recompile_across_used_len(self):
        """used_len is a traced leaf: one trace serves every length."""
        data = jnp.arange(16, dtype=jnp.int32)
        traces = [0]

        @jax.jit
        def f(arr, datum):
            traces[0] += 1
            return arr.count(datum), arr.section_sum()

        got = {}
        for length in (3, 9, 14):
            c, s = f(cpm_array(data, jnp.int32(length)), 4)
            got[length] = (int(c), int(s))
        assert traces[0] == 1, f"retraced {traces[0]}x across used_len values"
        for length, (c, s) in got.items():
            assert c == sum(1 for v in range(length) if v == 4)
            assert s == sum(range(length))

    def test_jit_returns_cpm_array(self):
        @jax.jit
        def grow(arr):
            return arr.insert(0, jnp.array([7, 7]))

        out = grow(cpm_array(jnp.arange(8), 4))
        assert isinstance(out, CPMArray)
        assert int(out.used_len) == 6
        np.testing.assert_array_equal(np.asarray(out.data)[:6],
                                      [7, 7, 0, 1, 2, 3])

    def test_batched_template_match_per_row_lengths(self):
        """window_valid broadcasts a per-batch used_len like every other op."""
        arr = CPMArray(jnp.arange(24.0).reshape(4, 6),
                       jnp.array([2, 4, 6, 3], jnp.int32))
        out = np.asarray(arr.template_match(jnp.array([1.0, 2.0])))
        assert out.shape == (4, 6)
        for row_i, used in enumerate([2, 4, 6, 3]):
            assert np.all(np.isinf(out[row_i, max(used - 1, 0):]))

    def test_vmap_per_row_lengths(self):
        batch = jnp.arange(24, dtype=jnp.int32).reshape(4, 6)
        lens = jnp.array([2, 4, 6, 3], jnp.int32)
        arr = CPMArray(batch, lens)
        sums = jax.vmap(lambda a: a.section_sum())(arr)
        want = [sum(range(i * 6, i * 6 + int(l))) for i, l in enumerate(lens)]
        np.testing.assert_array_equal(np.asarray(sums), want)
        sorted_arr = jax.vmap(lambda a: a.sort())(arr)
        assert isinstance(sorted_arr, CPMArray)
        np.testing.assert_array_equal(np.asarray(sorted_arr.used_len),
                                      np.asarray(lens))


# ---------------------------------------------------------------------------
# op table: step formulas against the paper bounds
# ---------------------------------------------------------------------------

class TestOpTable:
    def test_all_families_registered(self):
        assert set(cpm.FAMILIES) == {s.family for s in cpm.OP_TABLE.values()}

    @pytest.mark.parametrize("n", [64, 1000, 4096])
    def test_steps_report_within_bounds(self, n):
        arr = cpm_array(jnp.zeros(n))
        report = arr.steps_report(needle_len=8, bins=16, template_len=8)
        assert report["substring_match"] == 8
        assert report["histogram"] == 17
        assert report["compare"] == 1 and report["insert"] == 2
        assert report["section_sum"] <= 2 * int(np.ceil(np.sqrt(n))) + 1

    def test_bound_violation_raises(self):
        with pytest.raises(AssertionError):
            cpm.op_steps("section_sum", n=4096, section=4096)  # 1 section: N steps

    def test_backend_coverage_matches_table(self):
        for name in ("reference", "pallas"):
            ops = set(cpm.ops_for_backend(name))
            for fam in cpm.FAMILIES:
                assert any(cpm.OP_TABLE[o].family == fam for o in ops), \
                    f"{name} backend covers no {fam!r} op"
        assert {"section_sum", "global_limit",
                "super_sum", "super_limit"} <= set(cpm.ops_for_backend("mesh"))


# ---------------------------------------------------------------------------
# mesh backend under a real 2-device CPU mesh (subprocess)
# ---------------------------------------------------------------------------

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
import repro.cpm as cpm

assert len(jax.devices()) == 2
data = jnp.arange(13, dtype=jnp.int32)
for used in (13, 7):
    mesh = cpm.cpm_array(data, used, backend="mesh")
    ref = cpm.cpm_array(data, used, backend="reference")
    np.testing.assert_array_equal(np.asarray(mesh.section_sum()),
                                  np.asarray(ref.section_sum()))
    np.testing.assert_array_equal(np.asarray(mesh.super_sum()),
                                  np.asarray(ref.section_sum()))
    for mode in ("max", "min"):
        np.testing.assert_array_equal(np.asarray(mesh.global_limit(mode)),
                                      np.asarray(ref.global_limit(mode)))
        np.testing.assert_array_equal(np.asarray(mesh.super_limit(mode)),
                                      np.asarray(ref.global_limit(mode)))
    np.testing.assert_array_equal(np.asarray(mesh.compare(4, "lt")),
                                  np.asarray(ref.compare(4, "lt")))

# batched (R, N) rows reduce in one collective, per-row lengths respected
bdata = jnp.arange(26, dtype=jnp.int32).reshape(2, 13)
lens = jnp.asarray([13, 5], jnp.int32)
bmesh = cpm.CPMArray(bdata, lens, backend="mesh")
bref = cpm.CPMArray(bdata, lens, backend="reference")
for op in ("section_sum", "super_sum"):
    np.testing.assert_array_equal(np.asarray(getattr(bmesh, op)()),
                                  np.asarray(getattr(bref, op)()))
for mode in ("max", "min"):
    np.testing.assert_array_equal(np.asarray(bmesh.global_limit(mode)),
                                  np.asarray(bref.global_limit(mode)))
np.testing.assert_array_equal(np.asarray(bmesh.compare(4, "lt")),
                              np.asarray(bref.compare(4, "lt")))
print("MESH_BACKEND_OK")
"""


@pytest.mark.slow
def test_mesh_backend_two_devices():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, cwd=REPO_ROOT, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MESH_BACKEND_OK" in r.stdout
