"""`repro.cpm.program` — instruction streams, fusion, per-backend executors.

Covers the PR-4 acceptance criteria: recording is transparent (eager-equal
results), the fusing scheduler partitions at reduction boundaries, a
recorded 4+-op elementwise/local pipeline lowers to strictly fewer
``pallas_call``s than eager dispatch (ONE per fused group, jaxpr-walk
asserted) while staying bit-identical to eager reference execution, the
whole-program cycle-cost model matches jaxpr-measured scan trips, and the
serving commit path (`serve.program_paths`) fuses to a single launch.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.cpm as cpm
from repro.cpm import CPMArray, cpm_array, record, schedule
from repro.cpm.program import (apply_instruction, count_pallas_calls,
                               program_steps, scan_structured_steps,
                               scan_trip_count)
from repro.serve import program_paths

jax.config.update("jax_platform_name", "cpu")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def int_data(seed, n, lo=0, hi=9):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), lo, hi)


# ---------------------------------------------------------------------------
# recording: transparent tracing of CPMArray method calls
# ---------------------------------------------------------------------------

class TestRecording:
    def test_records_stream_and_returns_eager_values(self):
        data = int_data(0, 48)
        dev = cpm_array(data, 40)
        with record() as prog:
            d2 = dev.insert(3, jnp.array([90, 91]))
            flags = d2.compare(4, "lt")
            total = d2.section_sum()
        assert [i.op for i in prog] == ["insert", "compare", "section_sum"]
        ref = cpm_array(data, 40, backend="reference")
        e2 = ref.insert(3, jnp.array([90, 91]))
        np.testing.assert_array_equal(np.asarray(d2.data), np.asarray(e2.data))
        np.testing.assert_array_equal(np.asarray(flags),
                                      np.asarray(e2.compare(4, "lt")))
        assert int(total) == int(e2.section_sum())

    def test_nested_method_calls_record_once(self):
        """count() calls compare() internally — only the outer call is an
        instruction (the device sees one broadcast op)."""
        dev = cpm_array(int_data(1, 32), 32)
        with record() as prog:
            dev.count(4, "lt")
            dev.find_all(jnp.array([1, 2]), max_out=4)
        assert [i.op for i in prog] == ["count", "find_all"]

    def test_device_identity_restored_on_results(self):
        dev = cpm_array(jnp.arange(16), 10, backend="pallas", interpret=True)
        with record() as prog:
            out = dev.insert(2, jnp.array([5]))
        assert out.backend == "pallas" and out.interpret is True
        assert len(prog) == 1

    def test_record_does_not_nest(self):
        with record():
            with pytest.raises(RuntimeError):
                with record():
                    pass

    def test_non_linear_recording_raises(self):
        """Replay is strictly linear, so recording a call on a stale
        receiver (not the stream head) must raise, not silently replay
        against the wrong device state."""
        dev = cpm_array(jnp.arange(8), 8)
        with record():
            dev.insert(0, jnp.array([99, 98]))     # head moves past `dev`
            with pytest.raises(RuntimeError, match="non-linear"):
                dev.compare(5, "lt")

    def test_linear_producers_share_the_head(self):
        """Producers do not advance the head: many reads off one state —
        the example's filter/match pattern — stay recordable."""
        dev = cpm_array(jnp.arange(8), 8)
        with record() as prog:
            dev.compare(5, "lt")
            dev.template_match(jnp.array([1.0, 2.0]))
            d2 = dev.truncate(6)
            d2.section_sum()
        assert len(prog) == 4

    def test_no_recording_outside_context(self):
        dev = cpm_array(jnp.arange(8), 8)
        with record() as prog:
            pass
        dev.compare(3, "lt")                   # after the block: not traced
        assert len(prog) == 0

    def test_explicit_builder(self):
        prog = cpm.CPMProgram()
        prog.append("shift", start=1, end=5, shift=2, fill=None) \
            .append("section_sum", section=None)
        plan = schedule(prog)
        assert [g.kind for g in plan.groups] == ["fused", "boundary"]
        arr = cpm_array(jnp.arange(12), 9)
        final, outs = plan.run(arr, backend="reference")
        want = cpm_array(jnp.arange(12), 9, backend="reference").shift(1, 5, 2)
        np.testing.assert_array_equal(np.asarray(final.data),
                                      np.asarray(want.data))
        assert int(outs[1]) == int(want.section_sum())


# ---------------------------------------------------------------------------
# scheduling: fusable runs vs reduction boundaries, from the op table
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_reductions_are_boundaries(self):
        dev = cpm_array(int_data(2, 64), 50)
        with record() as prog:
            d = dev.shift(2, 20, 1)
            d.compare(3, "ge")
            d.section_sum()                    # wall
            d.activate(0, 30, 2)
            d.stencil((1.0, 2.0, 1.0))
            d.super_sum()                      # wall
            d.sort()                           # wall (whole-row reorder)
        plan = schedule(prog)
        assert [g.kind for g in plan.groups] == [
            "fused", "boundary", "fused", "boundary", "boundary"]
        assert plan.fused_group_count == 2
        assert [i.op for i in plan.groups[2].instructions] == [
            "activate", "stencil"]

    def test_fusable_set_reads_op_table(self):
        fus = cpm.fusable_ops()
        assert {"activate", "shift", "insert", "delete", "truncate",
                "compare", "substring_match", "template_match",
                "stencil"} <= fus
        for op in ("section_sum", "global_limit", "super_sum", "super_limit",
                   "sort", "histogram", "compact"):
            assert op not in fus

    def test_describe_names_groups(self):
        with record() as prog:
            cpm_array(jnp.arange(8), 8).compare(3, "lt")
        text = schedule(prog).describe()
        assert "fused" in text and "compare" in text


# ---------------------------------------------------------------------------
# acceptance: a recorded 4+-op pipeline fuses to ONE pallas_call
# ---------------------------------------------------------------------------

def _pipeline_program(dev):
    with record() as prog:
        d = dev.shift(2, 30, 3)
        d = d.insert(4, jnp.array([7, 8]))
        d.compare(20, "ge")
        d.activate(0, 40, 2)
        d.stencil((1.0, 2.0, 1.0))
    return prog


def _pipeline_eager(arr):
    d = arr.shift(2, 30, 3).insert(4, jnp.array([7, 8]))
    return (d.data, d.used_len, d.compare(20, "ge"), d.activate(0, 40, 2),
            d.stencil((1.0, 2.0, 1.0)))


class TestFusedPipeline:
    N, USED = 64, 50

    def _record(self):
        return _pipeline_program(cpm_array(int_data(3, self.N), self.USED))

    def test_strictly_fewer_pallas_calls_than_eager(self):
        plan = schedule(self._record())
        arr = cpm_array(int_data(3, self.N), self.USED, backend="pallas",
                        interpret=True)
        fused = count_pallas_calls(
            lambda a: plan.run(a, backend="pallas", interpret=True), arr)
        eager = count_pallas_calls(_pipeline_eager, arr)
        assert fused == plan.fused_group_count == 1
        assert eager == 5                      # one launch per dispatched op
        assert fused < eager

    def test_bit_identical_to_eager_reference(self):
        plan = schedule(self._record())
        data = int_data(3, self.N)
        final, outs = plan.run(cpm_array(data, self.USED), backend="pallas",
                               interpret=True)
        e_data, e_ul, *e_outs = _pipeline_eager(
            cpm_array(data, self.USED, backend="reference"))
        np.testing.assert_array_equal(np.asarray(final.data),
                                      np.asarray(e_data))
        assert int(final.used_len) == int(e_ul)
        got = [o for o in outs if o is not None]
        for g, e in zip(got, e_outs):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))

    def test_single_pallas_call_per_fused_group(self):
        """The CI fusion-smoke invariant: #pallas_calls == #fused groups +
        #pallas-dispatched boundary ops."""
        dev = cpm_array(int_data(4, 128), 100)
        with record() as prog:
            d = dev.shift(1, 60, 2)
            d.compare(5, "lt")
            d.section_sum()                    # boundary: its own kernel
            d.template_match(jnp.arange(4))
        plan = schedule(prog)
        assert plan.fused_group_count == 2
        arr = cpm_array(int_data(4, 128), 100)
        calls = count_pallas_calls(
            lambda a: plan.run(a, backend="pallas", interpret=True), arr)
        assert calls == 3                      # 2 fused groups + section_sum

    def test_every_fusable_op_matches_eager(self):
        """Per-op differential through the mega-kernel (group of one)."""
        n, used = 96, 70
        data = int_data(5, n)
        needle = data[10:13]
        cases = {
            "activate": lambda d: d.activate(3, 80, 4),
            "shift": lambda d: d.shift(5, 60, -2, fill=-1),
            "insert": lambda d: d.insert(7, jnp.array([41, 42, 43])),
            "delete": lambda d: d.delete(9, 3, fill=-7),
            "truncate": lambda d: d.truncate(33),
            "compare": lambda d: d.compare(4, "ge"),
            "compare_float": lambda d: d.compare(3.5, "lt"),
            "compare_mask": lambda d: d.compare(2, "eq", mask=3),
            "substring_start": lambda d: d.substring_match(needle),
            "substring_end": lambda d: d.substring_match(needle, where="end"),
            "template": lambda d: d.template_match(jnp.asarray(
                data[4:8], jnp.float32)),
            "stencil": lambda d: d.stencil((1.0, 2.0, 1.0)),
            "stencil_wrap": lambda d: d.stencil((0.5, 1.0, 0.5), wrap=True),
        }
        for name, call in cases.items():
            with record() as prog:
                got_rec = call(cpm_array(data, used))
            plan = schedule(prog)
            assert plan.groups[0].kind == "fused", name
            final, outs = plan.run(cpm_array(data, used), backend="pallas",
                                   interpret=True)
            want = call(cpm_array(data, used, backend="reference"))
            got = final if isinstance(want, CPMArray) else outs[0]
            if isinstance(want, CPMArray):
                np.testing.assert_array_equal(np.asarray(got.data),
                                              np.asarray(want.data), err_msg=name)
                np.testing.assert_array_equal(np.asarray(got.used_len),
                                              np.asarray(want.used_len),
                                              err_msg=name)
            else:
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want), err_msg=name)
            # recording itself returned the eager value
            rec = got_rec.data if isinstance(got_rec, CPMArray) else got_rec
            wnt = want.data if isinstance(want, CPMArray) else want
            np.testing.assert_array_equal(np.asarray(rec), np.asarray(wnt),
                                          err_msg=name)

    def test_batched_per_row_operands_fused(self):
        """The serving-commit shape: (B, cap) buffer, per-row positions."""
        buf = jnp.arange(40, dtype=jnp.int32).reshape(4, 10)
        used = jnp.array([5, 6, 7, 8], jnp.int32)
        preds = jnp.arange(400, 412, dtype=jnp.int32).reshape(4, 3)
        emit = jnp.array([1, 0, 3, 2], jnp.int32)
        dev = CPMArray(buf, used)
        with record() as prog:
            dev.insert(used, preds).truncate(used + emit)
        plan = schedule(prog)
        assert plan.fused_group_count == len(plan.groups) == 1
        ref, _ = plan.run(CPMArray(buf, used), backend="reference")
        pal, _ = plan.run(CPMArray(buf, used), backend="pallas",
                          interpret=True)
        np.testing.assert_array_equal(np.asarray(ref.data),
                                      np.asarray(pal.data))
        np.testing.assert_array_equal(np.asarray(ref.used_len),
                                      np.asarray(pal.used_len))
        np.testing.assert_array_equal(np.asarray(ref.used_len),
                                      np.asarray(used + emit))
        assert count_pallas_calls(
            lambda a: plan.run(a, backend="pallas", interpret=True)[0].data,
            CPMArray(buf, used)) == 1

    def test_jit_trace_time_recording(self):
        @jax.jit
        def traced(arr, vals):
            with record() as p:
                arr.insert(3, vals).truncate(10)
            out, _ = schedule(p).run(arr, backend="pallas", interpret=True)
            return out.data, out.used_len

        d, ul = traced(cpm_array(jnp.arange(16), 8), jnp.array([70, 71]))
        want = cpm_array(jnp.arange(16), 8, backend="reference") \
            .insert(3, jnp.array([70, 71])).truncate(10)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(want.data))
        assert int(ul) == int(want.used_len)


# ---------------------------------------------------------------------------
# executors: reference oracle, mesh mapping, boundary fallbacks
# ---------------------------------------------------------------------------

class TestExecutors:
    def test_reference_run_equals_recorded_eager(self):
        data = int_data(6, 80)
        dev = cpm_array(data, 64)
        with record() as prog:
            d = dev.delete(5, 4)
            rec_flags = d.compare(3, "lt")
            rec_sum = d.super_sum()
        final, outs = schedule(prog).run(cpm_array(data, 64),
                                         backend="reference")
        np.testing.assert_array_equal(np.asarray(final.data),
                                      np.asarray(d.data))
        np.testing.assert_array_equal(np.asarray(outs[1]),
                                      np.asarray(rec_flags))
        assert int(outs[2]) == int(rec_sum)

    def test_mesh_executor_matches_reference(self):
        """Mesh maps table-supported ops over shards, falls back to
        reference for the rest — same values either way (1-device mesh)."""
        data = int_data(7, 64)
        dev = cpm_array(data, 48)
        with record() as prog:
            dev.compare(4, "lt")
            dev.section_sum()
            dev.histogram(jnp.array([0, 3, 6, 9]))   # mesh-unsupported
            dev.super_limit("max")
        plan = schedule(prog)
        _, ref = plan.run(cpm_array(data, 48), backend="reference")
        _, mesh = plan.run(cpm_array(data, 48), backend="mesh")
        for r, m in zip(ref, mesh):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(m))

    def test_boundary_ops_run_on_pallas_where_supported(self):
        data = int_data(8, 64)
        dev = cpm_array(data, 64)
        with record() as prog:
            dev.histogram(jnp.array([0, 3, 6, 9]))
            dev.sort()
        plan = schedule(prog)
        arr = cpm_array(data, 64)
        _, outs = plan.run(arr, backend="pallas", interpret=True)
        _, ref = plan.run(arr, backend="reference")
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(ref[0]))

    def test_compact_boundary_reference_only(self):
        data = jnp.array([5, 1, 8, 2, 9, 3, 0, 0])
        dev = cpm_array(data, 6)
        with record() as prog:
            flags = dev.compare(4, "ge")
            dev.compact(flags, fill=-1)
        plan = schedule(prog)
        assert [g.kind for g in plan.groups] == ["fused", "boundary"]
        for backend in ("reference", "pallas"):
            final, _ = plan.run(cpm_array(data, 6), backend=backend,
                                interpret=True)
            np.testing.assert_array_equal(np.asarray(final.data)[:3],
                                          [5, 8, 9])
            assert int(final.used_len) == 3

    def test_apply_instruction_falls_back_when_unsupported(self):
        from repro.cpm.program.ir import Instruction
        arr = cpm_array(jnp.arange(8.0), 8)
        out = apply_instruction(arr, Instruction("sort", {"steps": None,
                                                          "fill": 0}),
                                backend="mesh")   # mesh has no sort: reference
        np.testing.assert_array_equal(np.asarray(out.data),
                                      np.sort(np.arange(8.0)))


# ---------------------------------------------------------------------------
# the whole-program cycle-cost model vs jaxpr-measured trips
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_steps_report_extends_to_programs(self):
        n = 4096
        dev = cpm_array(jnp.zeros(n, jnp.int32))
        with record() as prog:
            d = dev.insert(3, jnp.array([1, 2]))
            d.substring_match(jnp.arange(8))
            d.histogram(jnp.linspace(0, 9, 9).astype(jnp.int32))
            d.section_sum()
        report = prog.steps_report(n)
        assert report["0:insert"] == 2
        assert report["1:substring_match"] == 8
        assert report["2:histogram"] == 9      # M + 1 with M = 8 bins
        assert report["3:section_sum"] == cpm.op_steps("section_sum", n=n)
        assert report["total"] == sum(v for k, v in report.items()
                                      if k != "total")
        assert program_steps(prog, n) == report["total"]

    @pytest.mark.parametrize("n", [64, 1000, 4096])
    def test_scan_structured_matches_measured_trips(self, n):
        """The registered formulas ARE the reference lowering's trip counts
        (scan-structured ops), program-wide — PR-3's per-op assertion
        lifted to whole programs."""
        data = int_data(9, n)
        dev = cpm_array(data, n - 3)
        with record() as prog:
            dev.substring_match(data[:5])
            dev.template_match(jnp.asarray(data[2:9], jnp.float32))
            dev.super_sum()
            dev.compare(3, "lt")               # loop-free: contributes 0
            dev.super_limit("min")
        plan = schedule(prog)
        measured = scan_trip_count(
            lambda a: plan.run(a, backend="reference")[1],
            cpm_array(data, n - 3))
        assert measured == scan_structured_steps(prog, n)

    def test_predicted_steps_obey_paper_bounds(self):
        with record() as prog:
            cpm_array(jnp.zeros(4096)).super_sum()
        # op_steps inside is bound-checked; a violating section raises
        assert program_steps(prog, 4096) <= 2 * int(np.log2(4096)) + 1
        import repro.cpm.program.scheduler as S
        bad = cpm.CPMProgram().append("section_sum", section=4096)
        with pytest.raises(AssertionError):
            S.program_steps(bad, 4096)


# ---------------------------------------------------------------------------
# the serving hot path: verify -> truncate -> insert as one fused launch
# ---------------------------------------------------------------------------

class TestServingPathFusion:
    """CI fusion-smoke target: the recorded serving-path program under
    interpret=True — fused group count + single-launch invariant."""

    def _round(self):
        buf = jnp.zeros((4, 12), jnp.int32).at[:, :6].set(
            jnp.arange(24).reshape(4, 6))
        used = jnp.array([6, 6, 6, 6], jnp.int32)
        preds = jnp.arange(100, 112, dtype=jnp.int32).reshape(4, 3)
        emit = jnp.array([3, 1, 2, 0], jnp.int32)
        return buf, used, preds, emit

    def test_commit_program_is_one_fused_group(self):
        buf, used, preds, emit = self._round()
        _, plan = program_paths.record_commit_program(buf, used, preds, emit)
        assert len(plan.groups) == 1
        assert plan.groups[0].kind == "fused"
        assert [i.op for i in plan.program] == ["insert", "truncate"]

    def test_commit_single_pallas_launch(self):
        buf, used, preds, emit = self._round()

        def run(buf, used, preds, emit):
            return program_paths.commit_tokens(buf, used, preds, emit,
                                               backend="pallas",
                                               interpret=True)

        assert count_pallas_calls(run, buf, used, preds, emit) == 1

    def test_commit_backends_bit_identical(self):
        buf, used, preds, emit = self._round()
        rb, ru = program_paths.commit_tokens(buf, used, preds, emit,
                                             backend="reference")
        pb, pu = program_paths.commit_tokens(buf, used, preds, emit,
                                             backend="pallas",
                                             interpret=True)
        np.testing.assert_array_equal(np.asarray(rb), np.asarray(pb))
        np.testing.assert_array_equal(np.asarray(ru), np.asarray(pu))
        np.testing.assert_array_equal(np.asarray(ru), np.asarray(used + emit))
        # accepted prefixes are the predictions, live region only
        for r in range(4):
            np.testing.assert_array_equal(
                np.asarray(rb)[r, 6:6 + int(emit[r])],
                np.asarray(preds)[r, :int(emit[r])])

    def test_engine_spec_decode_matches_with_pallas_commit(self):
        """The engine produces identical tokens whether the commit program
        runs on the reference or the pallas (interpret) backend."""
        from repro.configs import all_configs
        from repro.models import lm
        from repro.serve import Engine, GenConfig

        cfg = all_configs()["granite-8b"].smoke()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        period = jnp.arange(5, dtype=jnp.int32) + 3
        batch = {"tokens": jnp.tile(period[None], (2, 4))}
        gen = GenConfig(max_new_tokens=8, ngram_spec=3)
        outs = {}
        for backend in ("reference", "pallas"):
            eng = Engine(cfg, params, max_len=64, cpm_backend=backend,
                         cpm_interpret=True if backend == "pallas" else None)
            toks, stats = eng.generate(batch, gen)
            outs[backend] = (np.asarray(toks), stats)
        np.testing.assert_array_equal(outs["reference"][0],
                                      outs["pallas"][0])
        assert outs["reference"][1] == outs["pallas"][1]
