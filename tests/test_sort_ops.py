"""§7.7 sorting: cross-backend bit-identity, step accounting, batched rows.

PR-4 satellite coverage the suite previously lacked: ``CPMArray.sort`` and
``hybrid_sort`` had no dedicated cross-backend differential, no
jaxpr-measured check of ``hybrid_sort_steps``, and no batched ``(R, N)``
regression test.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.cpm as cpm
from repro.cpm import CPMArray, cpm_array
from repro.cpm.program import scan_trip_count
from repro.cpm.reference import computable

jax.config.update("jax_platform_name", "cpu")


def pair(data, used):
    return (cpm_array(data, used, backend="reference"),
            cpm_array(data, used, backend="pallas", interpret=True))


class TestSortCrossBackend:
    @pytest.mark.parametrize("n,used", [(64, 64), (130, 100), (96, 17)])
    def test_int_bit_identity(self, n, used):
        data = jax.random.randint(jax.random.PRNGKey(n), (n,), -50, 50)
        ref, pal = pair(data, used)
        r, p = ref.sort(fill=-99), pal.sort(fill=-99)
        np.testing.assert_array_equal(np.asarray(r.data), np.asarray(p.data))
        np.testing.assert_array_equal(np.asarray(r.used_len),
                                      np.asarray(p.used_len))
        # sorted used prefix, untouched fill tail
        np.testing.assert_array_equal(np.asarray(r.data)[:used],
                                      np.sort(np.asarray(data)[:used]))
        np.testing.assert_array_equal(np.asarray(r.data)[used:],
                                      np.full(n - used, -99))

    @pytest.mark.parametrize("n,used", [(64, 64), (130, 77)])
    def test_float_bit_identity(self, n, used):
        data = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))
        ref, pal = pair(data, used)
        np.testing.assert_array_equal(np.asarray(ref.sort().data),
                                      np.asarray(pal.sort().data))

    def test_bounded_steps_cross_backend(self):
        """A bounded local phase (steps=k) runs the identical odd-even
        exchange schedule on both backends."""
        data = jax.random.randint(jax.random.PRNGKey(5), (48,), 0, 100)
        ref, pal = pair(data, 48)
        for steps in (1, 7, 16):
            np.testing.assert_array_equal(
                np.asarray(ref.sort(steps=steps).data),
                np.asarray(pal.sort(steps=steps).data))


class TestBatchedSort:
    def test_batched_rows_per_row_lengths(self):
        """(R, N) sort regression: per-row used prefixes sort independently,
        tails take fill, backends agree bit-for-bit."""
        data = jax.random.randint(jax.random.PRNGKey(6), (4, 33), -20, 20)
        lens = jnp.array([33, 17, 5, 0], jnp.int32)
        ref = CPMArray(data, lens, backend="reference").sort(fill=-1)
        pal = CPMArray(data, lens, backend="pallas",
                       interpret=True).sort(fill=-1)
        np.testing.assert_array_equal(np.asarray(ref.data),
                                      np.asarray(pal.data))
        for i, l in enumerate(np.asarray(lens)):
            np.testing.assert_array_equal(
                np.asarray(ref.data)[i, :l],
                np.sort(np.asarray(data)[i, :l]))
            np.testing.assert_array_equal(np.asarray(ref.data)[i, l:],
                                          np.full(33 - l, -1))

    def test_deep_batch_shape(self):
        data = jax.random.randint(jax.random.PRNGKey(7), (2, 3, 16), 0, 99)
        lens = jnp.array([[16, 9, 4], [1, 16, 12]], jnp.int32)
        ref = CPMArray(data, lens, backend="reference").sort()
        pal = CPMArray(data, lens, backend="pallas", interpret=True).sort()
        np.testing.assert_array_equal(np.asarray(ref.data),
                                      np.asarray(pal.data))
        assert ref.data.shape == (2, 3, 16)


class TestHybridSortSteps:
    @pytest.mark.parametrize("n", [64, 256, 1000])
    def test_formula_matches_measured_trips(self, n):
        """``hybrid_sort_steps(n)`` decomposes as the jaxpr-measured local
        exchange trips (~sqrt N odd-even cycles, a literal scan) plus the
        N/M global-move phase — and obeys the §7.7 2·sqrt(N)+1 claim."""
        x = jax.random.normal(jax.random.PRNGKey(n), (n,))
        measured = scan_trip_count(computable.hybrid_sort, x)
        m = computable.optimal_section(n)
        assert measured == m                       # the local phase, exactly
        assert computable.hybrid_sort_steps(n) == measured + -(-n // m)
        assert computable.hybrid_sort_steps(n) <= 2 * int(np.ceil(
            np.sqrt(n))) + 1
        # the same formula is the registered OP_TABLE entry (bound-checked)
        assert cpm.op_steps("hybrid_sort", n=n) == \
            computable.hybrid_sort_steps(n)

    def test_full_sort_trip_count_is_n(self):
        n = 48
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        measured = scan_trip_count(
            lambda v: computable.odd_even_sort(v), x)
        assert measured == n == cpm.op_steps("sort", n=n)

    def test_hybrid_sort_sorts(self):
        x = jax.random.normal(jax.random.PRNGKey(9), (120,))
        np.testing.assert_allclose(np.asarray(computable.hybrid_sort(x)),
                                   np.sort(np.asarray(x)), rtol=1e-6)
