"""Deprecated shim: moved to repro.cpm.reference.comparable (see repro.cpm)."""
import sys as _sys
from repro.cpm.reference import comparable as _mod
_sys.modules[__name__] = _mod
