"""Deprecated shim: moved to repro.cpm.reference.pe_array (see repro.cpm)."""
import sys as _sys
from repro.cpm.reference import pe_array as _mod
_sys.modules[__name__] = _mod
