"""Deprecated shim: moved to repro.cpm.reference.searchable (see repro.cpm)."""
import sys as _sys
from repro.cpm.reference import searchable as _mod
_sys.modules[__name__] = _mod
