"""Deprecated shim: moved to repro.cpm.reference.movable (see repro.cpm)."""
import sys as _sys
from repro.cpm.reference import movable as _mod
_sys.modules[__name__] = _mod
