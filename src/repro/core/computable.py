"""Deprecated shim: moved to repro.cpm.reference.computable (see repro.cpm)."""
import sys as _sys
from repro.cpm.reference import computable as _mod
_sys.modules[__name__] = _mod
