"""Deprecated shim: moved to repro.cpm.collectives (see repro.cpm)."""
import sys as _sys
from repro.cpm import collectives as _mod
_sys.modules[__name__] = _mod
