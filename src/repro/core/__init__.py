"""repro.core — the paper's contribution: CPM as a JAX operator library.

Four memory types (movable / searchable / comparable / computable) plus the
Rule-4 activation decoder, Rule-6 match reductions, and the pod-scale
collective embodiment.
"""

from . import collectives, comparable, computable, movable, pe_array, searchable
from .pe_array import (activation_mask, any_match, count_matches,
                       enumerate_matches, first_match, general_decoder)
from .movable import compact, delete, insert, move_object, shift_range
from .searchable import find_all, ngram_lookup, substring_match, verify_draft
from .comparable import compare, histogram, lex_compare_lt, quantile_threshold, topk_mask
from .computable import (count_disorder, detect_defects, hybrid_sort,
                         odd_even_sort, odd_even_step, optimal_section,
                         section_limit, section_sum, section_sum_2d,
                         stencil_1d, stencil_2d, template_match_1d,
                         template_match_2d)
from .collectives import (distributed_section_sum, grad_sync,
                          hierarchical_psum, ring_allreduce, ring_shift,
                          tree_allreduce)

__all__ = [
    "activation_mask", "general_decoder", "count_matches", "any_match",
    "first_match", "enumerate_matches",
    "shift_range", "insert", "delete", "compact", "move_object",
    "substring_match", "find_all", "verify_draft", "ngram_lookup",
    "compare", "lex_compare_lt", "histogram", "quantile_threshold", "topk_mask",
    "section_sum", "section_sum_2d", "section_limit", "optimal_section",
    "stencil_1d", "stencil_2d", "odd_even_step", "odd_even_sort",
    "hybrid_sort", "count_disorder", "detect_defects",
    "template_match_1d", "template_match_2d",
    "ring_shift", "ring_allreduce", "tree_allreduce", "hierarchical_psum",
    "grad_sync", "distributed_section_sum",
    "collectives", "comparable", "computable", "movable", "pe_array", "searchable",
]
