"""repro.core — deprecated alias of the `repro.cpm` reference backend.

The CPM operator library moved to ``repro.cpm`` (PR 2): the pure-`jnp`
implementations now live in ``repro.cpm.reference.*`` (plus
``repro.cpm.collectives``) behind the ``CPMArray`` / ``Backend`` surface.
This package re-exports every historical name so existing imports keep
working; new code should use ``repro.cpm``.
"""

import warnings as _warnings

from repro.cpm import collectives
from repro.cpm.reference import (comparable, computable, movable, pe_array,
                                 searchable)
from repro.cpm.reference.pe_array import (activation_mask, any_match,
                                          count_matches, enumerate_matches,
                                          first_match, general_decoder)
from repro.cpm.reference.movable import (compact, delete, insert, move_object,
                                         shift_range)
from repro.cpm.reference.searchable import (find_all, ngram_lookup,
                                            substring_match, verify_draft)
from repro.cpm.reference.comparable import (compare, histogram, lex_compare_lt,
                                            quantile_threshold, topk_mask)
from repro.cpm.reference.computable import (count_disorder, detect_defects,
                                            hybrid_sort, odd_even_sort,
                                            odd_even_step, optimal_section,
                                            section_limit, section_sum,
                                            section_sum_2d, stencil_1d,
                                            stencil_2d, template_match_1d,
                                            template_match_2d)
from repro.cpm.collectives import (distributed_section_sum, grad_sync,
                                   hierarchical_psum, ring_allreduce,
                                   ring_shift, tree_allreduce)

_warnings.warn(
    "repro.core is deprecated; use repro.cpm (CPMArray) or "
    "repro.cpm.reference.* directly.",
    DeprecationWarning, stacklevel=2)

__all__ = [
    "activation_mask", "general_decoder", "count_matches", "any_match",
    "first_match", "enumerate_matches",
    "shift_range", "insert", "delete", "compact", "move_object",
    "substring_match", "find_all", "verify_draft", "ngram_lookup",
    "compare", "lex_compare_lt", "histogram", "quantile_threshold", "topk_mask",
    "section_sum", "section_sum_2d", "section_limit", "optimal_section",
    "stencil_1d", "stencil_2d", "odd_even_step", "odd_even_sort",
    "hybrid_sort", "count_disorder", "detect_defects",
    "template_match_1d", "template_match_2d",
    "ring_shift", "ring_allreduce", "tree_allreduce", "hierarchical_psum",
    "grad_sync", "distributed_section_sum",
    "collectives", "comparable", "computable", "movable", "pe_array", "searchable",
]
