"""Production training driver.

On real hardware: ``python -m repro.launch.train --arch qwen2-72b
--shape train_4k --mesh production`` inside a jax.distributed-initialized
pod job.  On this CPU container: ``--mesh host --smoke`` trains the reduced
config end-to-end with the same code path (sharding rules, fault-tolerant
loop, checkpointing).
"""

import argparse
import dataclasses
import functools
import logging
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.train import (OptConfig, data, fault_tolerance as ft,
                         init_opt_state, make_train_step)

log = logging.getLogger("repro.launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["host", "production", "production-multi"],
                    default="host")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = SHAPES[args.shape]
    seq = args.seq_len or (64 if args.smoke else shape.seq_len)
    gbs = args.global_batch or (8 if args.smoke else shape.global_batch)

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multi"))
    ctx = shlib.make_ctx(mesh)
    shlib.set_sharding_ctx(ctx)
    log.info("mesh %s axes %s | arch %s (%.2fB params)", mesh.shape,
             mesh.axis_names, cfg.name, cfg.param_count() / 1e9)

    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(1, args.steps // 20))
    step = make_train_step(cfg, opt_cfg, num_microbatches=args.microbatches,
                           loss_chunk=min(1024, seq))

    def init_fn():
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params)}

    with jax.set_mesh(mesh):
        params_specs = shlib.param_specs(jax.eval_shape(init_fn)["params"], ctx)
        shardings = {"params": shlib.named_shardings(params_specs, mesh),
                     "opt": None}
        fcfg = ft.FaultConfig(ckpt_dir=args.ckpt_dir or f"/tmp/ckpt_{cfg.name}",
                              ckpt_every=args.ckpt_every)
        state, extra, start = ft.resume_or_init(fcfg, init_fn)
        pipe = data.make_pipeline(cfg, type("S", (), {
            "seq_len": seq, "global_batch": gbs})(),
            process_index=jax.process_index(),
            process_count=jax.process_count())
        if extra.get("data"):
            pipe.restore(extra["data"])

        jstep = jax.jit(step, donate_argnums=(0, 1))
        t0 = time.time()

        def step_fn(state, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            p, o, m = jstep(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, m

        def on_metrics(s, m):
            if (s + 1) % args.log_every == 0:
                dt = time.time() - t0
                toks = (s + 1 - start) * gbs * seq
                log.info("step %d loss %.4f lr %.2e | %.0f tok/s", s + 1,
                         float(m["loss"]), float(m["lr"]), toks / max(dt, 1e-9))

        state, hb = ft.run_loop(fcfg, state, step_fn, pipe, start, args.steps,
                                on_metrics)
        log.info("done: %d steps, %d stragglers", args.steps,
                 len(hb.straggler_steps))


if __name__ == "__main__":
    main()
