import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline inputs.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and only this entry point should see 512 host devices.

Per cell:
  1. full compile on the requested mesh -> proof of shardability +
     memory_analysis + optimized HLO collective schedule;
  2. (single-pod, --probe) 1-unit and 2-unit unrolled compiles ->
     per-chip FLOPs/bytes by linear extrapolation (cost_analysis visits
     while bodies once, so the full program can't be costed directly);
  3. roofline terms + MODEL_FLOPS ratio -> JSON record.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir artifacts/dryrun
"""

import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline
from repro.configs import SHAPES, all_configs, get_config, runnable_cells
from repro.distributed import sharding as shlib
from repro.launch import specs as speclib
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.train import OptConfig, train_step as ts

TRAIN_MICROBATCHES = int(os.environ.get("REPRO_MICROBATCHES", "8"))
GRAD_SYNC = os.environ.get("REPRO_GRAD_SYNC", "per_mb")
LOSS_CHUNK = 1024


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _batch_spec(name: str, shape: tuple, ctx) -> P:
    dp = ctx.dp
    if name == "pos_ids":                       # (3, B, S)
        spec = (None, dp, None)
    else:                                       # (B, ...) batch-major
        spec = (dp,) + (None,) * (len(shape) - 1)
    fixed = [a if a is None or shape[i] % ctx.axis_size(a) == 0 else None
             for i, a in enumerate(spec)]
    return P(*fixed)


_CACHE_RULES = {
    "k": ("b", "heads", None, None), "v": ("b", "heads", None, None),
    "C": ("b", "heads", None, None), "n": ("b", "heads", None),
    "h": ("b", "width"), "conv_buf": ("b", None, "width"),
    "c": ("b", "heads", None), "m": ("b", "heads", None),
    "len": (),
}


def _cache_spec(name: str, shape: tuple, ctx) -> P:
    """Cache leaves may carry a leading stacked-layer dim — rules are
    right-aligned.  kv heads shard over "model" when they divide it;
    otherwise the sequence (slot) axis does (flash-decoding style split-KV,
    XLA handles the sharded softmax reduction)."""
    rule = _CACHE_RULES.get(name)
    if rule is None:
        rule = ("b",) + (None,) * (len(shape) - 1)
    rule = (None,) * (len(shape) - len(rule)) + tuple(rule)

    def ax(r, dim):
        cands = {"b": [ctx.dp], "heads": [ctx.model_axis],
                 "seq": [ctx.model_axis], "width": [ctx.model_axis]}.get(r, [r])
        for a in cands:
            if a is None or dim % ctx.axis_size(a) == 0:
                return a
        return None

    fixed = [ax(r, shape[i]) for i, r in enumerate(rule)]
    # kv cache: if the head axis could not shard, shard the slot axis instead
    if name in ("k", "v") and len(shape) >= 4:
        hpos, spos = len(shape) - 3, len(shape) - 2
        if fixed[hpos] is None and shape[spos] % ctx.axis_size(ctx.model_axis) == 0:
            fixed[spos] = ctx.model_axis
    return P(*fixed)


def _tree_shardings(tree, spec_fn, ctx, mesh):
    def walk(node, name=""):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(x, name) for x in node]
            return type(node)(t)
        return NamedSharding(mesh, spec_fn(name, tuple(node.shape), ctx))
    return walk(tree)


def build_cell(arch: str, shape_name: str, mesh, probe_units: int = 0):
    """Returns (jitted_fn, example_args, cfg_used).

    Probe builds (1- and 2-unit configs) unroll every loop whose body
    cost_analysis would otherwise count once: layers (model unroll path),
    microbatches (forced to 1).  The loss-chunk scan remains (<=3% of
    step FLOPs, noted in EXPERIMENTS.md)."""
    cfg = get_config(arch)
    microbatches = TRAIN_MICROBATCHES
    if probe_units:
        unit = tuple(cfg.pattern)
        cfg = dataclasses.replace(
            cfg, n_layers=len(unit) * probe_units,
            n_enc_layers=min(cfg.n_enc_layers, probe_units))
        microbatches = 1
    shape = SHAPES[shape_name]
    # inference: weights replicated over dp (each DP replica serves whole
    # model, TP over "model" only) — no per-step FSDP gathers
    ctx = shlib.make_ctx(mesh, fsdp=(shape.kind == "train"),
                         pure_dp=bool(int(os.environ.get("REPRO_PURE_DP", "0")))
                         and shape.kind == "train")
    shlib.set_sharding_ctx(ctx)
    specs = speclib.input_specs(cfg, shape_name)

    params_sh = shlib.named_shardings(shlib.param_specs(specs["params"], ctx), mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_sh = {"mu": params_sh, "nu": params_sh, "step": repl}
        batch_sh = _tree_shardings(specs["batch"], _batch_spec, ctx, mesh)
        step = ts.make_train_step(cfg, OptConfig(), microbatches,
                                  remat=True, loss_chunk=LOSS_CHUNK)
        jitted = jax.jit(step, in_shardings=(params_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        args = (specs["params"], specs["opt_state"], specs["batch"])
    elif shape.kind == "prefill":
        batch_sh = _tree_shardings(specs["batch"], _batch_spec, ctx, mesh)
        fn = functools.partial(lm.prefill, cfg=cfg, max_len=shape.seq_len)
        step = lambda params, batch: fn(params, batch=batch)
        out_shape = jax.eval_shape(step, specs["params"], specs["batch"])
        logits_sh = NamedSharding(mesh, _cache_spec("logits", out_shape[0].shape, ctx))
        caches_out_sh = _tree_shardings(out_shape[1], _cache_spec, ctx, mesh)
        jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                         out_shardings=(logits_sh, caches_out_sh))
        args = (specs["params"], specs["batch"])
    else:                                       # decode
        cache_sh = _tree_shardings(specs["caches"], _cache_spec, ctx, mesh)
        tok_sh = NamedSharding(mesh, _batch_spec("tokens", specs["tokens_t"].shape, ctx))
        fn = functools.partial(lm.decode_step, cfg=cfg)
        step = lambda params, tokens_t, caches, pos: fn(
            params, tokens_t=tokens_t, caches=caches, pos=pos)
        out_shape = jax.eval_shape(step, specs["params"], specs["tokens_t"],
                                   specs["caches"], specs["pos"])
        logits_sh = NamedSharding(mesh, _cache_spec("logits", out_shape[0].shape, ctx))
        jitted = jax.jit(step, in_shardings=(params_sh, tok_sh, cache_sh, repl),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(2,))
        args = (specs["params"], specs["tokens_t"], specs["caches"], specs["pos"])
    return jitted, args, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, probe: bool = True,
             save_hlo: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev}

    t0 = time.time()
    jitted, args, cfg = build_cell(arch, shape_name, mesh)
    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gb": mem.argument_size_in_bytes / 2**30,
        "output_gb": mem.output_size_in_bytes / 2**30,
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "alias_gb": mem.alias_size_in_bytes / 2**30,
        "peak_device_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        / 2**30,
    }
    hlo = compiled.as_text()
    coll = roofline.parse_hlo(hlo, n_dev)
    rec["collectives"] = {"per_chip_gb": coll.per_chip_bytes / 2**30,
                          "by_kind_gb": {k: v / 2**30 for k, v in coll.by_kind.items()},
                          "op_counts": dict(coll.op_counts)}
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    del compiled, lowered, hlo

    if probe and not multi_pod:
        costs = {}
        for n in (1, 2):
            j, a, pcfg = build_cell(arch, shape_name, mesh, probe_units=n)
            c = j.lower(*a).compile()
            ca = c.cost_analysis()
            costs[n] = {"flops": float(ca.get("flops", 0.0)),
                        "bytes": float(ca.get("bytes accessed", 0.0))}
            del c
        unit_len = len(tuple(get_config(arch).pattern))
        n_units = get_config(arch).n_layers / unit_len
        unit = {k: costs[2][k] - costs[1][k] for k in ("flops", "bytes")}
        head = {k: costs[1][k] - unit[k] for k in ("flops", "bytes")}
        total = {k: head[k] + n_units * unit[k] for k in ("flops", "bytes")}
        # encoder layers scale with the same probe (enc probe had 1/2 layers)
        if get_config(arch).enc_dec:
            enc_units = get_config(arch).n_enc_layers
            # unit above includes one decoder unit + one encoder layer
            rec["note"] = ("enc-dec probe: unit includes 1 enc + 1 dec layer; "
                           f"extrapolated at {n_units} units (enc {enc_units})")
        rec["probe"] = {"cost_1unit": costs[1], "cost_2unit": costs[2],
                        "per_chip_flops": total["flops"],
                        "per_chip_bytes": total["bytes"]}
        shape = SHAPES[shape_name]
        mf = roofline.model_flops(get_config(arch), shape)
        hlo_flops_total = total["flops"] * n_dev
        rec["roofline"] = roofline.roofline_terms(
            total["flops"], total["bytes"], coll.per_chip_bytes)
        rec["model_flops"] = mf
        rec["hlo_flops_total"] = hlo_flops_total
        rec["useful_flops_ratio"] = mf / hlo_flops_total if hlo_flops_total else 0.0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--save-hlo")
    args = ap.parse_args()

    if args.all:
        import os as _os
        _os.makedirs(args.out_dir, exist_ok=True)
        fails = []
        for arch, shape in runnable_cells():
            for mesh_kind in (["single", "multi"] if args.mesh == "both"
                              else [args.mesh]):
                tag = f"{arch}__{shape}__{mesh_kind}"
                out = _os.path.join(args.out_dir, tag + ".json")
                if _os.path.exists(out):
                    print(f"skip {tag} (exists)")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                       "--out", out]
                if args.no_probe:
                    cmd.append("--no-probe")
                print(f"=== {tag}", flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    fails.append(tag)
        print("FAILED CELLS:", fails if fails else "none")
        sys.exit(1 if fails else 0)

    multi = args.mesh == "multi"
    try:
        rec = run_cell(args.arch, args.shape, multi, probe=not args.no_probe,
                       save_hlo=args.save_hlo)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    js = json.dumps(rec, indent=2, default=float)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
