"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state.  Single pod: 256 chips as (16, 16) = ("data", "model").  Multi-pod:
2 pods x 256 = (2, 16, 16) = ("pod", "data", "model") — the "pod" axis is
pure data parallelism across the cross-pod (DCN/optical) links, the inner
two axes live on the ICI torus.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
