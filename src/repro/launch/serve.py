"""Serving driver: batched generation with CPM-powered KV management,
prompt-lookup speculative decoding and comparable-memory sampling.

CPU container: ``python -m repro.launch.serve --arch granite-8b --smoke``.
"""

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.serve import Engine, GenConfig

log = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0)
    ap.add_argument("--spec", type=int, default=0,
                    help="prompt-lookup draft length (batched; greedy only)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.spec and args.temperature > 0:
        log.warning("--spec is greedy-only; temperature>0 disables "
                    "speculation and falls back to the scan decode path")
    mesh = make_host_mesh()
    shlib.set_sharding_ctx(shlib.make_ctx(mesh))

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    # speculative rounds may overshoot into cache slack; reserve draft room
    slack = 8 + 4 * args.spec
    engine = Engine(cfg, params, max_len=args.prompt_len + args.max_new + slack)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    gen = GenConfig(max_new_tokens=args.max_new, temperature=args.temperature,
                    top_k=args.top_k, top_p=args.top_p, ngram_spec=args.spec)

    t0 = time.time()
    out, stats = engine.generate({"tokens": tokens}, gen)
    jax.block_until_ready(out)
    dt = time.time() - t0
    new = args.batch * args.max_new
    log.info("generated %d tokens in %.2fs (%.1f tok/s)", new, dt, new / dt)
    if stats["proposed"]:
        log.info("spec decode: %d rounds, %d/%d draft tokens accepted "
                 "(rate %.2f)", stats["rounds"], stats["accepted"],
                 stats["proposed"], stats["acceptance_rate"])
    print(jnp.asarray(out)[:, -args.max_new:])


if __name__ == "__main__":
    main()
