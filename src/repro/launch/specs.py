"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero allocation."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import lm
from repro.train import optimizer as opt


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training/prefill batch input specs."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((b, s), jnp.int32)}
    if cfg.enc_dec:
        # stub audio frontend: precomputed frame embeddings, ~s/8 frames
        batch["src_embeds"] = sds((b, max(s // 8, 16), cfg.d_model), jnp.float32)
    if cfg.mrope_sections is not None:
        n_patch = min(256, s // 4)
        batch["patch_embeds"] = sds((b, n_patch, cfg.d_model), jnp.float32)
        batch["patch_pos"] = sds((b, n_patch), jnp.int32)
        batch["pos_ids"] = sds((3, b, s), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 kv_dtype=jnp.bfloat16) -> dict:
    """Decode-step input specs: one new token + a seq_len KV/state cache.

    ``kv_dtype=float8_e4m3fn`` models a quantized KV cache (KVQuant-style)
    for cells whose bf16 cache exceeds per-chip HBM."""
    b, s = shape.global_batch, shape.seq_len
    cross = max(s // 8, 16) if cfg.enc_dec else 0
    caches = jax.eval_shape(
        functools.partial(lm.init_caches, cfg, b, max_len=s, cross_len=cross,
                          dtype=kv_dtype))
    return {
        "tokens_t": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def params_specs(cfg: ModelConfig, dtype=None):
    """Abstract params.  ``dtype=bf16`` models serving weights (no fp32
    master copies at inference)."""
    tree = jax.eval_shape(functools.partial(lm.init_params, cfg),
                          jax.random.PRNGKey(0))
    if dtype is None:
        return tree
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
        if s.dtype == jnp.float32 and len(s.shape) >= 2 else s, tree)


def opt_specs(params_shape):
    return jax.eval_shape(opt.init_opt_state, params_shape)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """All abstract inputs for the step function of this cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        params = params_specs(cfg)
        return {"params": params, "opt_state": opt_specs(params),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg, jnp.bfloat16),
                "batch": batch_specs(cfg, shape)}
    return {"params": params_specs(cfg, jnp.bfloat16),
            **decode_specs(cfg, SHAPES[shape_name],
                           kv_dtype=kv_dtype_for(cfg, shape_name))}


def kv_dtype_for(cfg: ModelConfig, shape_name: str):
    """bf16 cache when it fits 256 chips; fp8 when it doesn't (big dense
    decode cells — see EXPERIMENTS.md capacity notes)."""
    shape = SHAPES[shape_name]
    kinds = cfg.layer_kinds()
    attn_layers = sum(k in ("attn", "attn_local") for k in kinds)
    slots = min(cfg.window, shape.seq_len) if cfg.window else shape.seq_len
    bytes_bf16 = (2 * attn_layers * shape.global_batch * cfg.n_kv_heads
                  * slots * cfg.dh * 2)
    if cfg.enc_dec:
        bytes_bf16 *= 2
    per_chip = bytes_bf16 / 256
    return jnp.bfloat16 if per_chip < 8e9 else jnp.float8_e4m3fn
