"""repro.obs — unified telemetry for the serving stack.

::

    metrics  ── process-global registry: counters/gauges/histograms with
    │           labeled series; JSON snapshot + Prometheus text exposition
    tracing  ── nestable spans in wall-clock AND virtual decode-step time
    │           (gateway tick, admission, prefill, decode chunk, park/
    │           restore), recorded host-side between compiled calls
    export   ── Chrome/Perfetto trace_event JSON + snapshot writers
    cycles   ── per-op-family predicted-vs-measured cycle ledger hooked
                into ``CPMProgram.steps_report()`` (model drift metric)

Contract (the PR-6 trace-safety rule extended to telemetry): all
recording is host-side Python between compiled calls — instrumented
serving code compiles **byte-identically** to uninstrumented code (same
program cache keys, same pallas launch counts, jaxpr-asserted in
``tests/test_obs.py``), and ``REPRO_OBS=0`` reduces every span/ledger
record to one env lookup while the metric instruments keep functioning
(the serving layers' ``stats()`` dicts are thin views over them).
"""

from . import cycles, export, live, metrics, promparse, slo, tracing
from .cycles import LEDGER, audit, drift_table
from .export import (chrome_trace, iter_trace_chunks, validate_chrome_trace,
                     write_metrics, write_trace, write_trace_stream)
from .live import TraceRing
from .metrics import (REGISTRY, counter, enabled, gauge, histogram,
                      prometheus_text, snapshot)
from .slo import BurnWindow, FlightRecorder, SloMonitor, allocator_state
from .tracing import TRACER, instant, span

__all__ = [
    "cycles", "export", "live", "metrics", "promparse", "slo", "tracing",
    "LEDGER", "audit", "drift_table",
    "chrome_trace", "iter_trace_chunks", "validate_chrome_trace",
    "write_metrics", "write_trace", "write_trace_stream",
    "TraceRing", "BurnWindow", "FlightRecorder", "SloMonitor",
    "allocator_state",
    "REGISTRY", "counter", "enabled", "gauge", "histogram",
    "prometheus_text", "snapshot",
    "TRACER", "instant", "span",
]
