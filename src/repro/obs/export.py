"""Exporters: Chrome/Perfetto ``trace_event`` JSON + snapshot files.

:func:`chrome_trace` renders a :class:`~repro.obs.tracing.Tracer` buffer
in the `Trace Event Format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
consumed by ``chrome://tracing`` and https://ui.perfetto.dev — open the
written ``trace.json`` there and every serving-layer span (gateway tick,
admission, prefill, decode chunk, park/restore) appears on its thread's
track, with the virtual decode-step clock riding in each event's ``args``
(``vstep``/``vdur``) and as a counter track.

Timestamps are microseconds relative to the first recorded event (the
format wants monotonic us; absolute epoch adds nothing to a single
process).  :func:`validate_chrome_trace` is the shared checker the tests,
the ``obs-smoke`` CI job and the benchmark all run over an exported file
— structural validity plus per-name span counts.
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Iterator

from .metrics import REGISTRY
from .tracing import TRACER, SpanEvent, Tracer

_PID = 1


def _resolve_events(source) -> list[SpanEvent]:
    """Accept a Tracer, anything with ``.events()`` (a ``live.TraceRing``),
    an iterable of SpanEvents, or None (the global tracer) — always
    returning one stable snapshot list."""
    if source is None:
        source = TRACER
    if isinstance(source, Tracer):
        return source.spans()
    events = getattr(source, "events", None)
    if callable(events):
        return list(events())
    return list(source)


def _meta_events(events: list[SpanEvent], process_name: str):
    """Metadata records + the tid remap shared by both renderers."""
    out: list[dict[str, Any]] = [{
        "ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    tids = sorted({e.tid for e in events})
    tid_map = {t: i + 1 for i, t in enumerate(tids)}
    for t, i in tid_map.items():
        out.append({"ph": "M", "pid": _PID, "tid": i,
                    "name": "thread_name",
                    "args": {"name": f"serve-thread-{i}"}})
    return out, tid_map


def _event_dict(e: SpanEvent, t0: float, tid_map: dict) -> dict:
    ts_us = (e.ts - t0) * 1e6
    args = dict(e.args or {})
    if e.vstep is not None:
        args["vstep"] = e.vstep
    if e.vdur is not None:
        args["vdur"] = e.vdur
    if e.cat.startswith("__counter__."):
        return {"ph": "C", "pid": _PID, "tid": tid_map[e.tid],
                "name": e.name, "cat": e.cat.split(".", 1)[1],
                "ts": ts_us, "args": args}
    if e.dur is None:
        return {"ph": "i", "s": "t", "pid": _PID,
                "tid": tid_map[e.tid], "name": e.name,
                "cat": e.cat, "ts": ts_us, "args": args}
    return {"ph": "X", "pid": _PID, "tid": tid_map[e.tid],
            "name": e.name, "cat": e.cat, "ts": ts_us,
            "dur": e.dur * 1e6, "args": args}


def _indent2(rendered: str) -> str:
    """Re-nest a depth-0 ``indent=1`` rendering to array-item depth, so
    streamed chunks concatenate byte-identically to the one-shot
    ``json.dumps(chrome_trace(...), indent=1)``."""
    return "\n".join("  " + ln for ln in rendered.splitlines())


def chrome_trace(tracer: Tracer | None = None,
                 process_name: str = "repro.serve") -> dict:
    """The tracer buffer as a ``{"traceEvents": [...]}`` JSON object."""
    events = _resolve_events(tracer)
    t0 = min((e.ts for e in events), default=0.0)
    out, tid_map = _meta_events(events, process_name)
    out.extend(_event_dict(e, t0, tid_map) for e in events)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def iter_trace_chunks(source=None, process_name: str = "repro.serve",
                      events_per_chunk: int = 256) -> Iterator[str]:
    """Stream a trace as text chunks that CONCATENATE to the exact JSON
    ``chrome_trace`` would produce — the live exporter behind
    ``GET /debug/trace`` and :func:`write_trace_stream`.

    ``source`` is a Tracer, a ``live.TraceRing``, an event iterable or
    None (the global tracer); the events are snapshotted once, then
    serialized ``events_per_chunk`` at a time, so peak memory is one
    chunk's text plus the (bounded, when ringed) snapshot — never the
    whole rendered JSON body of a week-long run."""
    events = _resolve_events(source)
    t0 = min((e.ts for e in events), default=0.0)
    meta, tid_map = _meta_events(events, process_name)
    head = json.dumps({"traceEvents": meta, "displayTimeUnit": "ms"},
                      indent=1)
    cut = head.rindex("]")                  # re-open the events array,
    while cut > 0 and head[cut - 1] in " \n":
        cut -= 1                            # splitting right after the
    head, tail = head[:cut], head[cut:]     # last metadata record
    yield head
    for i in range(0, len(events), events_per_chunk):
        batch = events[i:i + events_per_chunk]
        body = ",\n".join(_indent2(json.dumps(_event_dict(e, t0, tid_map),
                                              indent=1))
                          for e in batch)
        yield ",\n" + body
    yield tail


def write_trace_stream(path: str, source=None,
                       process_name: str = "repro.serve",
                       events_per_chunk: int = 256) -> int:
    """Chunked counterpart of :func:`write_trace` for live use: writes
    the stream chunk-by-chunk and returns the event count — the whole
    JSON text never exists in memory at once."""
    events = _resolve_events(source)
    with open(path, "w") as f:
        for chunk in iter_trace_chunks(events, process_name,
                                       events_per_chunk):
            f.write(chunk)
    return len(events)


def write_trace(path: str, tracer: Tracer | None = None) -> dict:
    """Write ``chrome_trace`` JSON to ``path``; returns the object."""
    obj = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return obj


def write_metrics(path: str, fmt: str = "prom") -> None:
    """Write the global registry snapshot — Prometheus text exposition
    (``fmt="prom"``) or the JSON snapshot (``fmt="json"``)."""
    if fmt == "prom":
        with open(path, "w") as f:
            f.write(REGISTRY.prometheus_text())
    elif fmt == "json":
        with open(path, "w") as f:
            json.dump(REGISTRY.snapshot(), f, indent=1, sort_keys=True)
    else:
        raise ValueError(f"unknown metrics format {fmt!r}")


def validate_chrome_trace(obj: dict) -> dict[str, int]:
    """Structural validation of a trace_event object; returns per-name
    event counts (what the CI job grades "≥1 span per layer" against).

    Raises ``ValueError`` on malformed events — missing required keys,
    negative durations, unknown phase types."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace_event object: no traceEvents key")
    counts: dict[str, int] = {}
    for e in obj["traceEvents"]:
        ph = e.get("ph")
        if ph not in ("X", "i", "I", "M", "C", "B", "E"):
            raise ValueError(f"unknown event phase {ph!r}: {e}")
        if "name" not in e or "pid" not in e:
            raise ValueError(f"event missing name/pid: {e}")
        if ph == "X":
            if "ts" not in e or "dur" not in e:
                raise ValueError(f"complete event missing ts/dur: {e}")
            if e["dur"] < 0:
                raise ValueError(f"negative duration: {e}")
        if ph != "M":
            counts[e["name"]] = counts.get(e["name"], 0) + 1
    return counts
