"""Exporters: Chrome/Perfetto ``trace_event`` JSON + snapshot files.

:func:`chrome_trace` renders a :class:`~repro.obs.tracing.Tracer` buffer
in the `Trace Event Format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
consumed by ``chrome://tracing`` and https://ui.perfetto.dev — open the
written ``trace.json`` there and every serving-layer span (gateway tick,
admission, prefill, decode chunk, park/restore) appears on its thread's
track, with the virtual decode-step clock riding in each event's ``args``
(``vstep``/``vdur``) and as a counter track.

Timestamps are microseconds relative to the first recorded event (the
format wants monotonic us; absolute epoch adds nothing to a single
process).  :func:`validate_chrome_trace` is the shared checker the tests,
the ``obs-smoke`` CI job and the benchmark all run over an exported file
— structural validity plus per-name span counts.
"""

from __future__ import annotations

import json
from typing import Any

from .metrics import REGISTRY
from .tracing import TRACER, Tracer

_PID = 1


def chrome_trace(tracer: Tracer | None = None,
                 process_name: str = "repro.serve") -> dict:
    """The tracer buffer as a ``{"traceEvents": [...]}`` JSON object."""
    tracer = tracer if tracer is not None else TRACER
    events = tracer.spans()
    t0 = min((e.ts for e in events), default=0.0)
    out: list[dict[str, Any]] = [{
        "ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    tids = sorted({e.tid for e in events})
    tid_map = {t: i + 1 for i, t in enumerate(tids)}
    for t, i in tid_map.items():
        out.append({"ph": "M", "pid": _PID, "tid": i,
                    "name": "thread_name",
                    "args": {"name": f"serve-thread-{i}"}})
    for e in events:
        ts_us = (e.ts - t0) * 1e6
        args = dict(e.args or {})
        if e.vstep is not None:
            args["vstep"] = e.vstep
        if e.vdur is not None:
            args["vdur"] = e.vdur
        if e.cat.startswith("__counter__."):
            out.append({"ph": "C", "pid": _PID, "tid": tid_map[e.tid],
                        "name": e.name, "cat": e.cat.split(".", 1)[1],
                        "ts": ts_us, "args": args})
        elif e.dur is None:
            out.append({"ph": "i", "s": "t", "pid": _PID,
                        "tid": tid_map[e.tid], "name": e.name,
                        "cat": e.cat, "ts": ts_us, "args": args})
        else:
            out.append({"ph": "X", "pid": _PID, "tid": tid_map[e.tid],
                        "name": e.name, "cat": e.cat, "ts": ts_us,
                        "dur": e.dur * 1e6, "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_trace(path: str, tracer: Tracer | None = None) -> dict:
    """Write ``chrome_trace`` JSON to ``path``; returns the object."""
    obj = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return obj


def write_metrics(path: str, fmt: str = "prom") -> None:
    """Write the global registry snapshot — Prometheus text exposition
    (``fmt="prom"``) or the JSON snapshot (``fmt="json"``)."""
    if fmt == "prom":
        with open(path, "w") as f:
            f.write(REGISTRY.prometheus_text())
    elif fmt == "json":
        with open(path, "w") as f:
            json.dump(REGISTRY.snapshot(), f, indent=1, sort_keys=True)
    else:
        raise ValueError(f"unknown metrics format {fmt!r}")


def validate_chrome_trace(obj: dict) -> dict[str, int]:
    """Structural validation of a trace_event object; returns per-name
    event counts (what the CI job grades "≥1 span per layer" against).

    Raises ``ValueError`` on malformed events — missing required keys,
    negative durations, unknown phase types."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace_event object: no traceEvents key")
    counts: dict[str, int] = {}
    for e in obj["traceEvents"]:
        ph = e.get("ph")
        if ph not in ("X", "i", "I", "M", "C", "B", "E"):
            raise ValueError(f"unknown event phase {ph!r}: {e}")
        if "name" not in e or "pid" not in e:
            raise ValueError(f"event missing name/pid: {e}")
        if ph == "X":
            if "ts" not in e or "dur" not in e:
                raise ValueError(f"complete event missing ts/dur: {e}")
            if e["dur"] < 0:
                raise ValueError(f"negative duration: {e}")
        if ph != "M":
            counts[e["name"]] = counts.get(e["name"], 0) + 1
    return counts
