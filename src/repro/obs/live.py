"""The live trace plane: a bounded ring of completed spans.

``write_trace`` renders the tracer's whole buffer at once — right for a
post-hoc bench artifact, wrong for a server that must stay up for weeks:
the buffer and the rendered JSON both grow without bound.  The live
plane inverts it:

  * a :class:`TraceRing` subscribes to the tracer as a **sink** and
    keeps only the newest ``capacity`` completed events (drops are
    counted, never silent);
  * ``export.iter_trace_chunks(ring)`` streams the ring as trace_event
    JSON chunks (``GET /debug/trace`` serves them with chunked
    transfer-encoding), so peak memory is one chunk plus the ring —
    O(capacity) regardless of run length;
  * the flight recorder (:mod:`repro.obs.slo`) dumps the same ring on an
    SLO burn alert, so a post-mortem always has the last-N spans that
    led up to the miss burst.

Everything here is host-side list work on already-completed events — the
PR-6/PR-9 trace-safety rule holds by construction (the ring never runs
inside a span, let alone inside a compiled call).
"""

from __future__ import annotations

import collections
import threading

from .tracing import SpanEvent, Tracer


class TraceRing:
    """Last-``capacity`` completed span events, fed by a tracer sink.

    Attach/detach is explicit so one process can run several rings at
    different depths (a deep one for ``/debug/trace``, a shallow one for
    the flight recorder) off the same tracer.
    """

    def __init__(self, capacity: int = 4096,
                 tracer: Tracer | None = None):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._dq: collections.deque[SpanEvent] = \
            collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0            # events pushed out of the ring so far
        self.total = 0              # events ever recorded into the ring
        self._tracer: Tracer | None = None
        if tracer is not None:
            self.attach(tracer)

    # -- sink protocol ------------------------------------------------------
    def __call__(self, ev: SpanEvent) -> None:
        with self._lock:
            if len(self._dq) == self.capacity:
                self.dropped += 1
            self._dq.append(ev)
            self.total += 1

    def attach(self, tracer: Tracer) -> "TraceRing":
        if self._tracer is not None:
            raise RuntimeError("ring already attached")
        tracer.add_sink(self)
        self._tracer = tracer
        return self

    def detach(self) -> None:
        if self._tracer is not None:
            self._tracer.remove_sink(self)
            self._tracer = None

    # -- snapshot surface (what the exporters consume) ----------------------
    def events(self) -> list[SpanEvent]:
        with self._lock:
            return list(self._dq)

    def last(self, n: int) -> list[SpanEvent]:
        with self._lock:
            if n >= len(self._dq):
                return list(self._dq)
            return list(self._dq)[-n:]

    def __len__(self) -> int:
        with self._lock:
            return len(self._dq)

    def clear(self) -> None:
        with self._lock:
            self._dq.clear()

    def stats(self) -> dict:
        with self._lock:
            return {"capacity": self.capacity, "len": len(self._dq),
                    "total": self.total, "dropped": self.dropped}
