"""The process-global metrics registry: counters, gauges, histograms.

Every serving layer used to grow its own ad-hoc counter fields
(``SessionPool.prefill_launches``, ``Gateway.stats()``, one-off bench
dicts).  This module is the single surface they all record through: a
metric is a named *family* with fixed label names, and each distinct
label-value combination is one **series** (``prefill_launches{pool="0"}``)
— the Prometheus data model, kept deliberately tiny.

Two hard rules keep telemetry out of the compiled programs:

  * **Host-side only.**  Instruments store plain Python numbers; callers
    record values they already hold on the host (counters bumped between
    compiled calls, gauges set from host mirrors).  Nothing here touches
    a device array, so instrumented code compiles byte-identically to
    uninstrumented code — the ``tests/test_obs.py`` jaxpr walks assert it.
  * **Views stay live.**  The serving layers' old dict-returning APIs
    (``SessionPool.stats()``, ``Gateway.stats()``) are thin views over
    these series, and their old attribute counters are properties backed
    by them — so the *instrument* is always functional (it is the
    accounting, not a copy of it).  ``REPRO_OBS=0`` therefore does not
    null the instruments; it only skips **registration** into the global
    registry (exports stay empty) and disables span/cycle recording
    (see :mod:`repro.obs.tracing` / :mod:`repro.obs.cycles`).

Snapshots: :func:`snapshot` returns a JSON-able ``{family: {series_key:
value}}`` dict; :func:`prometheus_text` renders the standard text
exposition format (``# HELP`` / ``# TYPE`` + one line per series).
"""

from __future__ import annotations

import itertools
import os
import threading
from typing import Any, Iterable

_HIST_DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)

#: the quantiles every histogram family also exposes as an estimated
#: Prometheus *summary* (``<name>_summary{quantile="..."}``) and in the
#: JSON snapshot (``p50``/``p90``/``p99``)
SUMMARY_QUANTILES = (0.5, 0.9, 0.99)


def enabled() -> bool:
    """Telemetry master switch (``REPRO_OBS=0`` disables).  Read per call
    — a dict lookup — so tests and benchmarks can flip it in-process."""
    return os.environ.get("REPRO_OBS", "1") != "0"


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


def escape_label_value(value: str) -> str:
    """Prometheus exposition escaping for label VALUES: backslash, double
    quote and newline must be escaped or the scrape line is ambiguous."""
    return (value.replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def escape_help(text: str) -> str:
    """``# HELP`` text escaping: backslash and newline only (quotes are
    legal in help text)."""
    return text.replace("\\", r"\\").replace("\n", r"\n")


def _fmt_labels(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{escape_label_value(v)}"'
                          for k, v in key) + "}"


class _Series:
    """One label-combination's value cell.  Plain host arithmetic — safe
    to bump from the gateway's tick worker thread (single-writer per
    series by the pool's discipline; reads are snapshots)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, amount=1):
        self.value += amount

    def set(self, value):
        self.value = value

    def reset(self):
        self.value = 0


class _HistSeries:
    """Cumulative-bucket histogram cell (Prometheus ``le`` semantics)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float):
        self.sum += value
        self.count += 1
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def reset(self):
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def quantile(self, q: float) -> float | None:
        """Estimate the q-quantile from the bucket layout, Prometheus
        ``histogram_quantile`` style: find the bucket the rank falls in
        and interpolate linearly inside it (uniform-within-bucket
        assumption).  Ranks landing in the ``+Inf`` tail clamp to the
        highest finite edge; an empty series returns ``None``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return None
        rank = q * self.count
        acc = 0
        for i, edge in enumerate(self.buckets):
            prev_acc = acc
            acc += self.counts[i]
            if acc >= rank and self.counts[i] > 0:
                lo = self.buckets[i - 1] if i > 0 else min(0.0, edge)
                frac = (rank - prev_acc) / self.counts[i]
                return lo + (edge - lo) * max(0.0, min(1.0, frac))
        # rank is in the +Inf bucket: the honest answer is "at least the
        # top edge" — report the top edge rather than inventing a value
        return self.buckets[-1] if self.buckets else None


class Metric:
    """A named family of series sharing one set of label names."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict[tuple[tuple[str, str], ...], Any] = {}
        self._lock = threading.Lock()

    def _new_series(self):
        return _Series()

    def labels(self, **labels):
        """The series for one label-value combination (created on first
        use).  Label names must match the family's declaration."""
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        key = _label_key({k: str(v) for k, v in labels.items()})
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = self._new_series()
        return s

    @property
    def default(self):
        """The label-less series (only valid when declared label-less)."""
        return self.labels()

    def series(self) -> dict[str, Any]:
        """``{rendered_label_string: value}`` snapshot."""
        return {_fmt_labels(k) or "": s.value
                for k, s in sorted(self._series.items())}


class Counter(Metric):
    kind = "counter"

    def inc(self, amount=1, **labels):
        self.labels(**labels).inc(amount)


class Gauge(Metric):
    kind = "gauge"

    def set(self, value, **labels):
        self.labels(**labels).set(value)


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = (),
                 buckets: tuple[float, ...] = _HIST_DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        super().__init__(name, help, labelnames)

    def _new_series(self):
        return _HistSeries(self.buckets)

    def observe(self, value, **labels):
        self.labels(**labels).observe(value)

    def series(self) -> dict[str, Any]:
        return {_fmt_labels(k): {"sum": s.sum, "count": s.count,
                                 "buckets": dict(zip(
                                     [str(b) for b in s.buckets] + ["+Inf"],
                                     list(itertools.accumulate(s.counts)))),
                                 "quantiles": {
                                     f"p{int(q * 100)}": s.quantile(q)
                                     for q in SUMMARY_QUANTILES}}
                for k, s in sorted(self._series.items())}


class Registry:
    """Name -> metric family.  One process-global instance (``REGISTRY``)
    backs the whole serving stack; tests may build private ones."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}
        self._lock = threading.Lock()

    def register(self, metric: Metric) -> Metric:
        with self._lock:
            have = self._metrics.get(metric.name)
            if have is not None:
                if type(have) is not type(metric) \
                        or have.labelnames != metric.labelnames:
                    raise ValueError(
                        f"metric {metric.name!r} re-registered with a "
                        f"different type/labels")
                return have
            self._metrics[metric.name] = metric
            return metric

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def metrics(self) -> list[Metric]:
        return list(self._metrics.values())

    def snapshot(self) -> dict:
        """JSON-able ``{name: {"kind", "help", "series": {...}}}``."""
        return {m.name: {"kind": m.kind, "help": m.help,
                         "series": m.series()}
                for m in sorted(self._metrics.values(),
                                key=lambda m: m.name)}

    def prometheus_text(self) -> str:
        """Standard Prometheus text exposition of every series.  Each
        histogram family is followed by a derived ``<name>_summary``
        family of TYPE ``summary`` carrying the bucket-estimated
        quantiles (:data:`SUMMARY_QUANTILES`) — scrapers that can't run
        ``histogram_quantile`` get p50/p90/p99 for free."""
        lines: list[str] = []
        for m in sorted(self._metrics.values(), key=lambda m: m.name):
            lines.append(f"# HELP {m.name} {escape_help(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                for key, s in sorted(m._series.items()):
                    acc = 0
                    for edge, c in zip(list(m.buckets) + ["+Inf"], s.counts):
                        acc += c
                        lk = _label_key(dict(key) | {"le": str(edge)})
                        lines.append(
                            f"{m.name}_bucket{_fmt_labels(lk)} {acc}")
                    lines.append(f"{m.name}_sum{_fmt_labels(key)} {s.sum}")
                    lines.append(f"{m.name}_count{_fmt_labels(key)} "
                                 f"{s.count}")
                sname = f"{m.name}_summary"
                lines.append(f"# HELP {sname} bucket-estimated quantiles "
                             f"of {m.name}")
                lines.append(f"# TYPE {sname} summary")
                for key, s in sorted(m._series.items()):
                    for q in SUMMARY_QUANTILES:
                        v = s.quantile(q)
                        if v is None:
                            continue
                        lk = _label_key(dict(key) | {"quantile": str(q)})
                        lines.append(f"{sname}{_fmt_labels(lk)} {v}")
                    lines.append(f"{sname}_sum{_fmt_labels(key)} {s.sum}")
                    lines.append(f"{sname}_count{_fmt_labels(key)} "
                                 f"{s.count}")
            else:
                for key, s in sorted(m._series.items()):
                    lines.append(f"{m.name}{_fmt_labels(key)} {s.value}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        """Drop every family (tests)."""
        with self._lock:
            self._metrics.clear()

    def reset(self) -> None:
        """Zero every series IN PLACE, keeping registrations and live
        series references valid.

        This is the test-isolation primitive: the registry is process
        global, so counters a suite bumps would otherwise satisfy (or
        pollute) another suite's assertions.  ``clear()`` is wrong for
        that job — the serving layers hold direct references to their
        series (``series_property`` views), and dropping the families
        would orphan them.  ``reset()`` zeroes the cells the views read
        through, so every layer keeps functioning from zero."""
        with self._lock:
            for m in self._metrics.values():
                with m._lock:
                    for s in m._series.values():
                        s.reset()


#: the process-global registry every serving layer records through
REGISTRY = Registry()


def _make(cls, name, help, labelnames, **kw):
    metric = cls(name, help, labelnames, **kw)
    if enabled():
        return REGISTRY.register(metric)
    # disabled: the instrument still works (the serving layers' stats
    # views read through it) but stays out of the global exports
    return metric


def counter(name: str, help: str = "",
            labelnames: Iterable[str] = ()) -> Counter:
    return _make(Counter, name, help, labelnames)


def gauge(name: str, help: str = "", labelnames: Iterable[str] = ()) -> Gauge:
    return _make(Gauge, name, help, labelnames)


def histogram(name: str, help: str = "", labelnames: Iterable[str] = (),
              buckets: tuple[float, ...] = _HIST_DEFAULT_BUCKETS) -> Histogram:
    return _make(Histogram, name, help, labelnames, buckets=buckets)


def series_property(key: str, store: str = "_obs_series",
                    doc: str | None = None) -> property:
    """A class attribute that reads/writes one registry series — the
    migration shim that keeps a layer's legacy counter attributes
    (``pool.prefill_launches``) working as thin views over the registry.
    The instance must hold a ``{key: series}`` dict at ``store``."""
    def getter(self):
        return getattr(self, store)[key].value

    def setter(self, value):
        getattr(self, store)[key].set(value)

    return property(getter, setter, doc=doc)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    return REGISTRY.prometheus_text()
