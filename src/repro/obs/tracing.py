"""Nestable spans over the serving stack, in wall-clock AND virtual time.

A span is one timed region of the host-side serving loop — gateway tick,
admission bucket, prefill launch, decode chunk, park/restore — recorded
with two clocks:

  * **wall clock** (``time.perf_counter``): what the machine spent.  The
    decode chunk's dispatch is async, so its span measures *dispatch +
    any blocking the caller already does* — the tracer never inserts a
    ``block_until_ready`` of its own (that would add a device sync inside
    the serving loop; ``tests/test_obs.py`` asserts it doesn't).
  * **virtual time** (the pool's ``decode_steps`` counter): the
    deterministic scheduling clock every SLO and benchmark is graded in.
    Callers pass ``vclock=lambda: pool.decode_steps``; the span records
    it at entry and exit, so a Perfetto view can correlate wall hiccups
    with virtual-step progress.

Recording is strictly host-side (list appends + ``perf_counter`` calls)
and happens **between** compiled calls, never inside a trace — the PR-6
trace-safety rule.  With ``REPRO_OBS=0`` every ``span()`` yields a shared
null handle and records nothing, so a disabled tracer costs one env
lookup per call and the event buffer never grows.

Spans nest per-thread (the gateway's tick worker thread gets its own
stack and its events carry its tid), and :mod:`repro.obs.export` renders
the buffer as Chrome/Perfetto ``trace_event`` JSON.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable

from .metrics import enabled


@dataclasses.dataclass
class SpanEvent:
    """One finished span (or instant, ``dur is None``)."""
    name: str
    cat: str
    ts: float                      # perf_counter seconds at entry
    dur: float | None              # wall seconds (None for instants)
    tid: int
    depth: int                     # nesting depth within its thread
    vstep: int | None = None       # virtual decode-step clock at entry
    vdur: int | None = None        # virtual steps elapsed inside the span
    args: dict[str, Any] | None = None


class _SpanHandle:
    """Live span: mutate ``args`` inside the ``with`` to annotate it."""

    __slots__ = ("args",)

    def __init__(self, args: dict[str, Any]):
        self.args = args


_NULL_HANDLE = _SpanHandle({})


class Tracer:
    """The event buffer + per-thread span stacks.

    The buffer is a deque: unbounded by default (post-hoc ``write_trace``
    wants everything), boundable via :meth:`set_limit` for live serving —
    a week-long run then holds at most ``max_events`` completed spans and
    the streaming exporter (:mod:`repro.obs.live`) renders from its own
    bounded ring.  **Sinks** are the live-plane hook: every completed
    event is also pushed to each registered callback (host-side, after
    the span closed — never inside it)."""

    def __init__(self, max_events: int | None = None):
        self.events: collections.deque[SpanEvent] = \
            collections.deque(maxlen=max_events)
        self._sinks: list[Callable[[SpanEvent], None]] = []
        self._local = threading.local()
        self._lock = threading.Lock()

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    @property
    def max_events(self) -> int | None:
        return self.events.maxlen

    def set_limit(self, max_events: int | None) -> None:
        """Bound (or unbound) the buffer in place, keeping the newest
        events.  The live HTTP plane calls this so the process-global
        tracer cannot grow without bound under continuous traffic."""
        with self._lock:
            self.events = collections.deque(self.events, maxlen=max_events)

    def add_sink(self, sink: Callable[[SpanEvent], None]) -> None:
        """Register a per-event callback (e.g. a ``live.TraceRing``).
        Sinks run on the recording thread between compiled calls — keep
        them O(1) host work."""
        with self._lock:
            if sink not in self._sinks:
                self._sinks.append(sink)

    def remove_sink(self, sink: Callable[[SpanEvent], None]) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    def _emit(self, ev: SpanEvent) -> None:
        with self._lock:
            self.events.append(ev)
            sinks = list(self._sinks)
        for sink in sinks:
            sink(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve",
             vclock: Callable[[], int] | None = None,
             args: dict[str, Any] | None = None):
        """Record one nested region.  ``vclock`` (if given) is sampled at
        entry and exit on the host — pass a closure over a host mirror,
        never a device read."""
        if not enabled():
            yield _NULL_HANDLE
            return
        depth = self._depth()
        self._local.depth = depth + 1
        handle = _SpanHandle(dict(args) if args else {})
        v0 = int(vclock()) if vclock is not None else None
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            dur = time.perf_counter() - t0
            v1 = int(vclock()) if vclock is not None else None
            self._local.depth = depth
            ev = SpanEvent(name=name, cat=cat, ts=t0, dur=dur,
                           tid=threading.get_ident(), depth=depth,
                           vstep=v0,
                           vdur=(v1 - v0) if v0 is not None else None,
                           args=handle.args or None)
            self._emit(ev)

    def instant(self, name: str, cat: str = "serve",
                vstep: int | None = None,
                args: dict[str, Any] | None = None) -> None:
        """Record a zero-duration marker (page grants, packed commits)."""
        if not enabled():
            return
        ev = SpanEvent(name=name, cat=cat, ts=time.perf_counter(),
                       dur=None, tid=threading.get_ident(),
                       depth=self._depth(),
                       vstep=int(vstep) if vstep is not None else None,
                       args=dict(args) if args else None)
        self._emit(ev)

    def counter(self, name: str, value, cat: str = "serve") -> None:
        """Record a Chrome counter-track sample (rendered as ``ph: "C"``)."""
        if not enabled():
            return
        ev = SpanEvent(name=name, cat="__counter__." + cat,
                       ts=time.perf_counter(), dur=None,
                       tid=threading.get_ident(), depth=0,
                       args={"value": value})
        self._emit(ev)

    def spans(self, name: str | None = None) -> list[SpanEvent]:
        """Snapshot of recorded events, optionally filtered by exact name."""
        with self._lock:
            evs = list(self.events)
        if name is None:
            return evs
        return [e for e in evs if e.name == name]

    def clear(self) -> None:
        with self._lock:
            self.events.clear()


#: the process-global tracer the serving layers record through
TRACER = Tracer()


def span(name: str, cat: str = "serve",
         vclock: Callable[[], int] | None = None,
         args: dict[str, Any] | None = None):
    """``with tracing.span("pool.decode_chunk", vclock=...):`` — the
    module-level convenience over :data:`TRACER`."""
    return TRACER.span(name, cat=cat, vclock=vclock, args=args)


def instant(name: str, cat: str = "serve", vstep: int | None = None,
            args: dict[str, Any] | None = None) -> None:
    TRACER.instant(name, cat=cat, vstep=vstep, args=args)
