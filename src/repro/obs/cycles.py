"""Cycle accounting: the paper's predicted budgets vs the measured lowering.

The repo's cost model predicts every program's *concurrent-step* cycles
from the op table (``~1`` universal, ``~M`` local, ``~√N`` global,
``~log N`` super — §4–§8), and PR 3/4 proved the formulas equal the
jaxpr-measured scan trip counts per op and per program.  This module
makes that comparison a **live metric**: a process-global
:class:`CycleLedger` accumulates, per op *family*,

  * ``predicted``       — op-table concurrent-step cycles,
  * ``predicted_scan``  — the scan-lowered share of them (the part a
    jaxpr walk can measure as ``lax.scan`` trips),
  * ``measured_trips``  — scan trips measured from the reference lowering,
  * ``launches``        — ``pallas_call`` count of the op's lowering,

and exposes ``drift = measured_trips - predicted_scan`` per family — the
model-vs-measured drift metric.  A healthy build holds drift at 0; any
nonzero drift means an op's lowering no longer matches its registered
budget (exactly the regression the SIMDRAM-style measured-vs-modeled
evaluation methodology exists to catch).

Feeding the ledger:

  * ``CPMProgram.steps_report()`` is hooked — every report (i.e. every
    scheduled program whose cycles anyone asks about) records its
    predicted cycles here, per family, when telemetry is on;
  * :func:`audit` replays a program instruction-by-instruction on a
    concrete device, measuring each instruction's reference lowering
    (scan trips + pallas launches) via ``jax.make_jaxpr`` — host-side
    tracing, never inside an active jax trace (the PR-6 rule; audits
    refuse to run mid-trace).

All recording is host arithmetic; ``REPRO_OBS=0`` turns both feeds off.
"""

from __future__ import annotations

import dataclasses
import threading

from .metrics import counter, enabled


@dataclasses.dataclass
class FamilyCycles:
    """Accumulated cycle accounting for one op family."""
    family: str
    predicted: int = 0
    predicted_scan: int = 0
    measured_trips: int = 0
    launches: int = 0
    instructions: int = 0
    audited: int = 0               # instructions with a measured lowering

    @property
    def drift(self) -> int:
        return self.measured_trips - self.predicted_scan


class CycleLedger:
    def __init__(self):
        self._families: dict[str, FamilyCycles] = {}
        self._lock = threading.Lock()
        self._predicted = counter(
            "repro_cycles_predicted_total",
            "op-table predicted concurrent-step cycles", ("family",))
        self._measured = counter(
            "repro_cycles_measured_trips_total",
            "jaxpr-measured scan trips of audited lowerings", ("family",))
        self._launches = counter(
            "repro_cycles_pallas_launches_total",
            "pallas_call count of audited lowerings", ("family",))

    def _fam(self, family: str) -> FamilyCycles:
        f = self._families.get(family)
        if f is None:
            f = self._families[family] = FamilyCycles(family)
        return f

    # -- feeds ---------------------------------------------------------------
    def note_predicted(self, family: str, steps: int,
                       scan_steps: int = 0) -> None:
        """One instruction's predicted cycles (``steps_report`` hook)."""
        with self._lock:
            f = self._fam(family)
            f.predicted += steps
            f.predicted_scan += scan_steps
            f.instructions += 1
        self._predicted.inc(steps, family=family)

    def note_measured(self, family: str, trips: int, launches: int,
                      predicted: int = 0, scan_predicted: int = 0) -> None:
        """One audited instruction: measured lowering next to its budget."""
        with self._lock:
            f = self._fam(family)
            f.predicted += predicted
            f.predicted_scan += scan_predicted
            f.measured_trips += trips
            f.launches += launches
            f.instructions += 1
            f.audited += 1
        if predicted:
            self._predicted.inc(predicted, family=family)
        self._measured.inc(trips, family=family)
        self._launches.inc(launches, family=family)

    # -- views ---------------------------------------------------------------
    def drift_table(self) -> list[dict]:
        """Per-family rows, audited families first, worst drift on top."""
        with self._lock:
            fams = [dataclasses.asdict(f) | {"drift": f.drift}
                    for f in self._families.values()]
        return sorted(fams, key=lambda r: (-r["audited"], -abs(r["drift"]),
                                           r["family"]))

    def format_drift_table(self) -> str:
        rows = self.drift_table()
        head = (f"{'family':<10} {'instrs':>6} {'predicted':>9} "
                f"{'pred_scan':>9} {'meas_trips':>10} {'launches':>8} "
                f"{'drift':>5}")
        lines = [head, "-" * len(head)]
        for r in rows:
            lines.append(
                f"{r['family']:<10} {r['instructions']:>6} "
                f"{r['predicted']:>9} {r['predicted_scan']:>9} "
                f"{r['measured_trips']:>10} {r['launches']:>8} "
                f"{r['drift']:>5}")
        return "\n".join(lines)

    def clear(self) -> None:
        with self._lock:
            self._families.clear()


#: the process-global ledger
LEDGER = CycleLedger()


def _family_of(op: str) -> str:
    from repro.cpm.optable import OP_TABLE
    from repro.cpm.program.ir import DERIVED_METHODS
    spec = OP_TABLE.get(DERIVED_METHODS.get(op, op))
    return spec.family if spec is not None else "unknown"


def _scan_share(op: str, steps: int) -> int:
    """The scan-lowered share of one instruction's predicted cycles —
    ``scheduler.scan_structured_steps`` per instruction: scan-structured
    ops count fully, minus the Rule-6 drain step of derived methods
    (``find_all`` = ``substring_match`` + 1), which is not a scan trip."""
    from repro.cpm.program.ir import DERIVED_METHODS
    from repro.cpm.program.scheduler import _SCAN_STRUCTURED
    if op not in _SCAN_STRUCTURED:
        return 0
    return steps - (1 if op in DERIVED_METHODS else 0)


def note_report(prog, n: int, report: dict) -> None:
    """The ``CPMProgram.steps_report`` hook: fold one report's per-
    instruction predicted cycles into the ledger (telemetry on only)."""
    if not enabled():
        return
    for i, instr in enumerate(prog.instructions):
        steps = report.get(f"{i}:{instr.op}")
        if steps is None:
            continue
        LEDGER.note_predicted(_family_of(instr.op), int(steps),
                              _scan_share(instr.op, int(steps)))


def audit(prog, device, section: int | None = None,
          ledger: CycleLedger | None = None) -> list[dict]:
    """Measure a program's reference lowering instruction-by-instruction
    against its op-table budget, on a concrete ``device`` (a CPMArray).

    For each instruction: predicted cycles come from the op-table formula
    at the device's ``n``; measured scan trips and pallas-launch counts
    come from a ``jax.make_jaxpr`` walk of the instruction's *reference*
    replay against the evolving device state (pure host-side tracing).
    Results land in the ledger per family and are returned per
    instruction.  Refuses to run inside an active jax trace (timing and
    tracing there would be staged, not real — the PR-6 rule).
    """
    import jax

    from repro.cpm.program import executors, introspect
    from repro.cpm.program.scheduler import instruction_steps
    if not jax.core.trace_state_clean():
        raise RuntimeError(
            "cycles.audit() inside an active jax trace would measure "
            "staged tracing, not execution; audit eagerly between "
            "compiled calls")
    led = ledger if ledger is not None else LEDGER
    n = device.n
    rows: list[dict] = []
    dev = device
    for instr in prog.instructions:
        predicted = instruction_steps(instr, n, section=section)
        scan_pred = _scan_share(instr.op, predicted)

        def lowered(d, instr=instr):
            out = executors.apply_instruction(d, instr, backend="reference")
            return out.data if hasattr(out, "data") else out

        trips = introspect.scan_trip_count(lowered, dev)
        launches = introspect.count_pallas_calls(lowered, dev)
        fam = _family_of(instr.op)
        if enabled():
            led.note_measured(fam, trips, launches, predicted=predicted,
                              scan_predicted=scan_pred)
        rows.append({"op": instr.op, "family": fam, "n": n,
                     "predicted": predicted, "predicted_scan": scan_pred,
                     "measured_trips": trips, "launches": launches,
                     "drift": trips - scan_pred})
        out = executors.apply_instruction(dev, instr, backend="reference")
        if type(out) is type(dev):
            dev = out                   # transforms advance the stream head
    return rows


def drift_table() -> list[dict]:
    return LEDGER.drift_table()
