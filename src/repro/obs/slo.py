"""SLO burn-rate monitoring + the flight recorder.

The gateway already *grades* every finished request against its
``deadline_steps`` SLO in virtual decode-step time.  This module makes
those grades actionable, SRE-style:

  * :class:`SloMonitor` keeps a rolling window of grades and computes the
    **burn rate** — the fraction of the error budget (``1 - objective``)
    the recent miss rate is consuming — over a **fast** and a **slow**
    window.  An alert fires only when BOTH exceed their thresholds: the
    fast window catches the burst, the slow window confirms it is
    sustained rather than one unlucky tick (the classic multi-window
    multi-burn-rate rule).  Both windows are measured in virtual decode
    steps, so alerts are deterministic and replayable.
  * On alert the :class:`FlightRecorder` dumps everything a post-mortem
    needs — the last-N spans from the live ring, the full metrics
    registry (JSON + Prometheus text), and the allocator's page-table
    state — written **atomically** (temp file + ``os.replace``), so a
    crash mid-dump can never leave a torn artifact.

Everything is host-side accounting between compiled calls, per the
trace-safety rule: recording a grade is a deque append, a burn-rate
check is arithmetic over at most the slow window's events, and the dump
reads only host mirrors (the allocator's state vectors are NumPy views
of metadata the pool already syncs).
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Any, Callable

import numpy as np

from . import export, metrics
from .live import TraceRing

_SLO_FAMILIES = {
    "alerts": metrics.counter(
        "repro_slo_alerts_total", "burn-rate alerts fired", ("monitor",)),
    "burn": metrics.gauge(
        "repro_slo_burn_rate", "latest burn rate per window",
        ("monitor", "window")),
}


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One rolling window: ``steps`` of virtual time and the burn-rate
    multiple that must be exceeded inside it."""
    steps: int
    threshold: float


#: defaults follow the SRE-book shape scaled to decode-step time: a short
#: window that must burn fast (a miss burst) and a long window that must
#: still be burning (sustained, not noise)
DEFAULT_FAST = BurnWindow(steps=64, threshold=8.0)
DEFAULT_SLOW = BurnWindow(steps=512, threshold=2.0)


class SloMonitor:
    """Multi-window burn-rate monitor over the gateway's deadline grades.

    Wire it with ``Gateway(..., slo_monitor=monitor)``; the gateway calls
    :meth:`record` once per graded finish (met or missed), stamped with
    the pool's decode-step clock.
    """

    def __init__(self, objective: float = 0.95,
                 fast: BurnWindow = DEFAULT_FAST,
                 slow: BurnWindow = DEFAULT_SLOW,
                 recorder: "FlightRecorder | None" = None,
                 cooldown_steps: int | None = None,
                 min_events: int = 4,
                 on_alert: Callable[[dict], None] | None = None,
                 name: str = "gw"):
        if not 0.0 < objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), got {objective}")
        if fast.steps > slow.steps:
            raise ValueError("fast window must not exceed the slow window")
        self.objective = objective
        self.budget = 1.0 - objective
        self.fast, self.slow = fast, slow
        self.recorder = recorder
        self.min_events = min_events
        self.cooldown_steps = (cooldown_steps if cooldown_steps is not None
                               else fast.steps)
        self.on_alert = on_alert
        self.name = name
        self._events: collections.deque[tuple[int, bool]] = \
            collections.deque()          # (step, met), pruned to slow window
        self.alerts: list[dict] = []
        self.recorded = 0
        self._last_alert_step: int | None = None
        self._series = {
            "alerts": _SLO_FAMILIES["alerts"].labels(monitor=name),
            "burn_fast": _SLO_FAMILIES["burn"].labels(monitor=name,
                                                      window="fast"),
            "burn_slow": _SLO_FAMILIES["burn"].labels(monitor=name,
                                                      window="slow"),
        }

    # -- accounting ---------------------------------------------------------
    def record(self, met: bool, step: int) -> dict | None:
        """One graded finish at virtual time ``step``.  Returns the alert
        dict if this grade tripped the monitor, else None."""
        self._events.append((int(step), bool(met)))
        self.recorded += 1
        horizon = step - self.slow.steps
        while self._events and self._events[0][0] < horizon:
            self._events.popleft()
        return self._evaluate(int(step))

    def _window_rates(self, now: int, window: BurnWindow) -> tuple[float, int]:
        lo = now - window.steps
        total = misses = 0
        for step, met in self._events:
            if step >= lo:
                total += 1
                misses += not met
        return (misses / total if total else 0.0), total

    def burn_rate(self, now: int, window: BurnWindow) -> float:
        """Miss rate inside the window as a multiple of the error budget
        (1.0 = exactly consuming budget; >1 = on track to blow it)."""
        rate, _ = self._window_rates(now, window)
        return rate / self.budget

    def attainment(self, now: int | None = None,
                   window: BurnWindow | None = None) -> float | None:
        """Fraction of grades met inside ``window`` (default: slow)."""
        if now is None:
            now = self._events[-1][0] if self._events else 0
        rate, total = self._window_rates(now, window or self.slow)
        return (1.0 - rate) if total else None

    def _evaluate(self, now: int) -> dict | None:
        fast_rate, fast_n = self._window_rates(now, self.fast)
        slow_rate, slow_n = self._window_rates(now, self.slow)
        fast_burn = fast_rate / self.budget
        slow_burn = slow_rate / self.budget
        self._series["burn_fast"].set(fast_burn)
        self._series["burn_slow"].set(slow_burn)
        if fast_n < self.min_events:
            return None
        if fast_burn <= self.fast.threshold or \
                slow_burn <= self.slow.threshold:
            return None
        if self._last_alert_step is not None and \
                now < self._last_alert_step + self.cooldown_steps:
            return None
        alert = {
            "step": now,
            "objective": self.objective,
            "fast": {"window_steps": self.fast.steps, "burn": fast_burn,
                     "threshold": self.fast.threshold, "events": fast_n},
            "slow": {"window_steps": self.slow.steps, "burn": slow_burn,
                     "threshold": self.slow.threshold, "events": slow_n},
            "dump": None,
        }
        self._last_alert_step = now
        if self.recorder is not None:
            alert["dump"] = self.recorder.dump(
                reason=f"slo_burn step={now} fast={fast_burn:.1f}x "
                       f"slow={slow_burn:.1f}x", extra={"alert": {
                           k: v for k, v in alert.items() if k != "dump"}})
        self.alerts.append(alert)
        self._series["alerts"].inc()
        if self.on_alert is not None:
            self.on_alert(alert)
        return alert

    def state(self) -> dict:
        """JSON-able live view (served by ``GET /v1/stats``)."""
        now = self._events[-1][0] if self._events else 0
        return {
            "objective": self.objective,
            "recorded": self.recorded,
            "attainment_slow": self.attainment(now),
            "burn_fast": self.burn_rate(now, self.fast),
            "burn_slow": self.burn_rate(now, self.slow),
            "alerts": len(self.alerts),
            "last_alert_step": self._last_alert_step,
        }


def allocator_state(pool) -> dict:
    """The pool allocator's page-table state as JSON-able host data: slot
    occupancy, sub-page occupancy, and each used slot's ordered page
    list — exactly what a post-mortem of a page-pressure incident needs."""
    alloc = pool.alloc
    slots = np.asarray(alloc.state_vector()).astype(int).tolist()
    pages = np.asarray(alloc.page_state_vector()).astype(int).tolist()
    used = [s for s, st in enumerate(slots) if st != 0]
    return {
        "n_slots": len(slots),
        "n_pages": len(pages),
        "slot_state": slots,
        "page_state": pages,
        "free_slots": alloc.free_count(),
        "free_pages": alloc.page_free_count(),
        "page_lists": {str(s): list(alloc.pages(s)) for s in used},
        "page_size": pool.page_size,
        "total_pages": pool.total_pages,
    }


class FlightRecorder:
    """Atomic post-mortem dumps: last-N spans + registry + page table.

    One ``dump()`` writes ``flight_<seq>.json`` under ``directory`` via a
    same-directory temp file and ``os.replace`` — readers can never see a
    torn file.  The payload round-trips through the repo's own
    validators: ``trace`` through ``validate_chrome_trace`` and
    ``metrics_prom`` through ``obs.promparse.parse``.
    """

    def __init__(self, directory: str = "artifacts/flightrec",
                 ring: TraceRing | None = None, pool=None,
                 last_n: int = 256, max_dumps: int = 16):
        self.directory = directory
        self.ring = ring
        self.pool = pool
        self.last_n = last_n
        self.max_dumps = max_dumps
        self._seq = 0

    def dump(self, reason: str, extra: dict | None = None) -> str | None:
        """Write one dump; returns its path (None once ``max_dumps`` is
        reached — a flapping alert must not fill the disk)."""
        if self._seq >= self.max_dumps:
            return None
        os.makedirs(self.directory, exist_ok=True)
        spans = self.ring.last(self.last_n) if self.ring is not None else []
        payload: dict[str, Any] = {
            "reason": reason,
            "wall_time": time.time(),
            "seq": self._seq,
            "ring": self.ring.stats() if self.ring is not None else None,
            "trace": export.chrome_trace(spans),
            "metrics": metrics.REGISTRY.snapshot(),
            "metrics_prom": metrics.REGISTRY.prometheus_text(),
            "allocator": (allocator_state(self.pool)
                          if self.pool is not None else None),
            "extra": extra,
        }
        path = os.path.join(self.directory, f"flight_{self._seq:04d}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._seq += 1
        return path
