"""A strict mini-parser for the Prometheus text exposition format.

This is the *validator* half of ``Registry.prometheus_text()``: the tests
and the CI gates parse the rendered exposition back with it instead of
grepping for substrings, so escaping bugs, HELP/TYPE ordering bugs and
histogram inconsistencies fail loudly.  It deliberately implements only
what the registry emits (and what a scrape endpoint must get right):

  * comment discipline — every family has exactly one ``# HELP`` and one
    ``# TYPE``, HELP first, both before any of the family's samples, and
    a family's samples are contiguous (no interleaving);
  * label parsing with full value UN-escaping (``\\\\``, ``\\"``,
    ``\\n``) via a character-level scanner, not a regex that a quote in
    a label value would defeat;
  * histogram consistency — ``_bucket`` series are cumulative and
    non-decreasing in ``le`` order, the ``+Inf`` bucket equals
    ``_count``, and ``_sum``/``_count`` exist per label set;
  * summary consistency — ``quantile`` labels are floats in [0, 1].

``parse`` raises :class:`ValueError` with the offending line number on
any violation; on success it returns ``{family: Family}`` for structured
assertions.
"""

from __future__ import annotations

import dataclasses
import math

_SUFFIXES = {
    "histogram": ("_bucket", "_sum", "_count"),
    "summary": ("", "_sum", "_count"),
    "counter": ("",),
    "gauge": ("",),
    "untyped": ("",),
}


@dataclasses.dataclass
class Sample:
    name: str                       # full sample name (with suffix)
    labels: dict[str, str]
    value: float
    line: int


@dataclasses.dataclass
class Family:
    name: str
    help: str
    type: str
    samples: list[Sample] = dataclasses.field(default_factory=list)

    def series(self, suffix: str = "") -> dict[tuple, float]:
        """``{sorted-label-items: value}`` for one suffix's samples."""
        return {tuple(sorted(s.labels.items())): s.value
                for s in self.samples if s.name == self.name + suffix}


def _unescape(raw: str, line_no: int) -> str:
    out, i = [], 0
    while i < len(raw):
        c = raw[i]
        if c == "\\":
            if i + 1 >= len(raw):
                raise ValueError(f"line {line_no}: dangling backslash")
            nxt = raw[i + 1]
            if nxt == "\\":
                out.append("\\")
            elif nxt == '"':
                out.append('"')
            elif nxt == "n":
                out.append("\n")
            else:
                raise ValueError(
                    f"line {line_no}: bad escape \\{nxt} in label value")
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _parse_labels(body: str, line_no: int) -> dict[str, str]:
    """Scan ``name="value",...`` with escaping; ``body`` excludes braces."""
    labels: dict[str, str] = {}
    i = 0
    while i < len(body):
        eq = body.find("=", i)
        if eq < 0:
            raise ValueError(f"line {line_no}: label without '='")
        name = body[i:eq].strip()
        if not name.replace("_", "a").isalnum():
            raise ValueError(f"line {line_no}: bad label name {name!r}")
        if eq + 1 >= len(body) or body[eq + 1] != '"':
            raise ValueError(f"line {line_no}: unquoted label value")
        j = eq + 2
        while j < len(body):                 # find the closing quote,
            if body[j] == "\\":              # skipping escaped chars
                j += 2
            elif body[j] == '"':
                break
            else:
                j += 1
        if j >= len(body) or body[j] != '"':
            raise ValueError(f"line {line_no}: unterminated label value")
        if name in labels:
            raise ValueError(f"line {line_no}: duplicate label {name!r}")
        labels[name] = _unescape(body[eq + 2:j], line_no)
        i = j + 1
        if i < len(body):
            if body[i] != ",":
                raise ValueError(
                    f"line {line_no}: expected ',' between labels")
            i += 1
    return labels


def _parse_sample(line: str, line_no: int) -> Sample:
    brace = line.find("{")
    if brace >= 0:
        close = line.rfind("}")
        if close < brace:
            raise ValueError(f"line {line_no}: unbalanced braces")
        name = line[:brace]
        labels = _parse_labels(line[brace + 1:close], line_no)
        rest = line[close + 1:].strip()
    else:
        parts = line.split(None, 1)
        if len(parts) != 2:
            raise ValueError(f"line {line_no}: sample missing value")
        name, rest = parts[0], parts[1]
        labels = {}
    if not name or not name.replace("_", "a").replace(":", "a").isalnum():
        raise ValueError(f"line {line_no}: bad metric name {name!r}")
    val = rest.split()[0] if rest.split() else ""
    try:
        value = float(val.replace("+Inf", "inf").replace("-Inf", "-inf"))
    except ValueError:
        raise ValueError(f"line {line_no}: bad sample value {val!r}")
    return Sample(name=name, labels=labels, value=value, line=line_no)


def _family_of(sample_name: str, families: dict[str, Family]) -> Family | None:
    """Longest-prefix match of a sample name onto a declared family,
    honoring the family type's legal suffixes."""
    for cut in (sample_name, sample_name.rsplit("_", 1)[0]):
        fam = families.get(cut)
        if fam is None:
            continue
        suffix = sample_name[len(cut):]
        if suffix in _SUFFIXES.get(fam.type, ("",)):
            return fam
    return None


def _check_histogram(fam: Family) -> None:
    by_key: dict[tuple, list[Sample]] = {}
    for s in fam.samples:
        if s.name == fam.name + "_bucket":
            key = tuple(sorted((k, v) for k, v in s.labels.items()
                               if k != "le"))
            by_key.setdefault(key, []).append(s)
    sums = fam.series("_sum")
    counts = fam.series("_count")
    for key, buckets in by_key.items():
        def le(s):
            v = s.labels.get("le")
            if v is None:
                raise ValueError(f"line {s.line}: _bucket without le label")
            return math.inf if v == "+Inf" else float(v)
        ordered = sorted(buckets, key=le)
        values = [b.value for b in ordered]
        if values != sorted(values):
            raise ValueError(
                f"{fam.name}: buckets not cumulative for labels {key}")
        if le(ordered[-1]) != math.inf:
            raise ValueError(f"{fam.name}: no +Inf bucket for labels {key}")
        if key not in counts or key not in sums:
            raise ValueError(
                f"{fam.name}: missing _sum/_count for labels {key}")
        if values[-1] != counts[key]:
            raise ValueError(
                f"{fam.name}: +Inf bucket {values[-1]} != _count "
                f"{counts[key]} for labels {key}")


def _check_summary(fam: Family) -> None:
    for s in fam.samples:
        if s.name == fam.name:
            q = s.labels.get("quantile")
            if q is None:
                raise ValueError(
                    f"line {s.line}: summary sample without quantile label")
            qf = float(q)
            if not 0.0 <= qf <= 1.0:
                raise ValueError(
                    f"line {s.line}: quantile {q} outside [0, 1]")


def parse(text: str) -> dict[str, Family]:
    """Parse + validate one exposition; raises ValueError on violations."""
    families: dict[str, Family] = {}
    pending_help: tuple[str, str] | None = None
    current: Family | None = None
    closed: set[str] = set()                 # families whose block ended
    for line_no, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            name, help_text = parts[0], parts[1] if len(parts) > 1 else ""
            if name in families:
                raise ValueError(f"line {line_no}: duplicate HELP {name}")
            if pending_help is not None:
                raise ValueError(
                    f"line {line_no}: HELP {name} before TYPE "
                    f"{pending_help[0]}")
            pending_help = (name, help_text)
        elif line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split()
            if len(parts) != 2:
                raise ValueError(f"line {line_no}: malformed TYPE line")
            name, kind = parts
            if pending_help is None or pending_help[0] != name:
                raise ValueError(
                    f"line {line_no}: TYPE {name} without preceding HELP")
            if kind not in _SUFFIXES:
                raise ValueError(f"line {line_no}: unknown type {kind!r}")
            if current is not None:
                closed.add(current.name)
            current = Family(name=name, help=pending_help[1], type=kind)
            families[name] = current
            pending_help = None
        elif line.startswith("#"):
            continue                         # plain comment
        else:
            sample = _parse_sample(line, line_no)
            fam = _family_of(sample.name, families)
            if fam is None:
                raise ValueError(
                    f"line {line_no}: sample {sample.name!r} has no "
                    f"preceding HELP/TYPE declaration")
            if fam.name in closed:
                raise ValueError(
                    f"line {line_no}: sample {sample.name!r} after family "
                    f"{fam.name} block ended (interleaved families)")
            if fam is not current:
                raise ValueError(
                    f"line {line_no}: sample {sample.name!r} outside its "
                    f"family's contiguous block")
            fam.samples.append(sample)
    if pending_help is not None:
        raise ValueError(f"dangling HELP {pending_help[0]} without TYPE")
    for fam in families.values():
        if fam.type == "histogram":
            _check_histogram(fam)
        elif fam.type == "summary":
            _check_summary(fam)
    return families
