"""The CPM instruction set, registered once with its cost model.

Every paper operation is an :class:`OpSpec` carrying its *concurrent step
count* formula — the paper's instruction-cycle currency — plus the paper
bound it must stay under.  ``CPMArray.steps_report()`` and
``benchmarks/run.py``'s ``cpm_ops`` scenario both read this table, so the
complexity claims of §3–§8 are validated from a single source of truth.

Formula arguments (all keyword, extras ignored):
  n        physical array length (PE count)
  m        op-specific size: needle length (search), bin count (histogram),
           tap/template length (stencil / template match)
  section  §7.4 section size M (defaults to the optimal ~sqrt(n))
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable


def optimal_section(n: int) -> int:
    """M ~ sqrt(N) minimizes the two-phase cost M + N/M (§7.4).

    The single definition — ``reference.computable`` and the backends
    import it from here.
    """
    return max(1, int(math.isqrt(max(1, n))))


def two_phase_steps(n, section=None, **_):
    """§7.4/§7.5 concurrent steps: M in-section + N/M cross-section."""
    m = section or optimal_section(n)
    return m + -(-n // m)


def _clog2(k: int) -> int:
    """Tree levels to combine ``k`` items: ceil(log2(k)), 0 for k <= 1."""
    return (k - 1).bit_length() if k > 1 else 0


def log_depth_steps(n, section=None, **_):
    """§8 super-connected steps: log-depth trees in both phases,
    clog2(M) + clog2(N/M) ~ log2(N) — the √N → log N upgrade."""
    m = section or optimal_section(n)
    return _clog2(m) + _clog2(-(-n // m))


def log_depth_bound(n, **_):
    """The §8 claim this repo enforces: ~2·log2(N) + 1 concurrent steps."""
    return 2 * _clog2(max(2, n)) + 1


_two_phase = two_phase_steps


@dataclass(frozen=True)
class OpSpec:
    name: str
    family: str                       # activate | move | search | compare | compute
    paper: str                        # section of the source paper
    steps: Callable[..., int]         # concurrent-step formula (registered once)
    bound: Callable[..., int]         # the paper's claimed ceiling
    backends: tuple[str, ...]         # which backends implement it
    #: elementwise/local ops whose kernel body reads only the resident VMEM
    #: block (plus a bounded neighbor window) — the fusing scheduler may run
    #: a run of these as ONE Pallas mega-kernel.  Reductions and sorts read
    #: or reorder the whole row and are fusion-group boundaries.
    fusable: bool = False
    #: wall-clock cost metadata for the cost-aware scheduler
    #: (``repro.cpm.program.costmodel``): how many full row read/write
    #: passes the *lowering* makes (None = reuse the concurrent-step
    #: formula) and how many kernel launches the eager pallas path pays.
    #: Distinct from ``steps``: e.g. ``truncate`` is 1 concurrent step but
    #: 0 row passes / 0 launches — only the length register moves.
    passes: Callable[..., int] | None = None
    eager_launches: int = 1

    def check(self, **sizes) -> int:
        """Evaluate the formula and assert it obeys the paper bound."""
        got, cap = self.steps(**sizes), self.bound(**sizes)
        if got > cap:
            raise AssertionError(
                f"{self.name}: steps formula {got} exceeds paper bound {cap} "
                f"for sizes {sizes}")
        return got


_RPM = ("reference", "pallas", "mesh")
_RP = ("reference", "pallas")

OP_TABLE: dict[str, OpSpec] = {spec.name: spec for spec in [
    # -- activate (Rule 4) --------------------------------------------------
    OpSpec("activate", "activate", "§3.3 R4",
           steps=lambda **_: 1, bound=lambda **_: 1, backends=_RP,
           fusable=True),
    # -- move (§4) ----------------------------------------------------------
    OpSpec("shift", "move", "§4.1",
           steps=lambda **_: 1, bound=lambda **_: 1, backends=_RP,
           fusable=True),
    OpSpec("insert", "move", "§4.2",       # range shift + broadcast write
           steps=lambda **_: 2, bound=lambda **_: 2, backends=_RP,
           fusable=True),
    OpSpec("delete", "move", "§4.2",
           steps=lambda **_: 2, bound=lambda **_: 2, backends=_RP,
           fusable=True),
    OpSpec("truncate", "move", "§4.2",     # range delete at the tail: the
           steps=lambda **_: 1,            # used-length register updates,
           bound=lambda **_: 1,            # entries stay put (O(1))
           backends=_RPM, fusable=True,
           passes=lambda **_: 0, eager_launches=0),
    OpSpec("compact", "move", "§4.2",      # stable pack of kept items: the
           steps=lambda n, **_: _clog2(n),     # TPU-native cumsum-gather is
           bound=lambda n, **_: _clog2(n) + 1, # log-depth (paper: per-object
           backends=_RP),                      # range moves)
    # -- search (§5) --------------------------------------------------------
    OpSpec("substring_match", "search", "§5.1",
           steps=lambda m, **_: m, bound=lambda m, **_: m, backends=_RP,
           fusable=True),
    # -- compare (§6) -------------------------------------------------------
    OpSpec("compare", "compare", "§6.1",
           steps=lambda **_: 1, bound=lambda **_: 1, backends=_RPM,
           fusable=True),
    OpSpec("histogram", "compare", "§6.3", # one compare+count per section edge
           steps=lambda m, **_: m + 1, bound=lambda m, **_: m + 1,
           backends=_RP),
    # -- compute / reduce (§7) ----------------------------------------------
    OpSpec("section_sum", "compute", "§7.4",
           steps=_two_phase,
           bound=lambda n, **_: 2 * math.ceil(math.sqrt(max(1, n))) + 1,
           backends=_RPM),
    OpSpec("global_limit", "compute", "§7.5",
           steps=_two_phase,
           bound=lambda n, **_: 2 * math.ceil(math.sqrt(max(1, n))) + 1,
           backends=_RPM),
    OpSpec("super_sum", "compute", "§8",       # log-depth phase-1 + phase-2
           steps=log_depth_steps, bound=log_depth_bound, backends=_RPM),
    OpSpec("super_limit", "compute", "§8",
           steps=log_depth_steps, bound=log_depth_bound, backends=_RPM),
    OpSpec("sort", "compute", "§7.7",      # full odd-even transposition sort
           steps=lambda n, **_: n, bound=lambda n, **_: n, backends=_RP),
    OpSpec("hybrid_sort", "compute", "§7.7",   # local phase of the sqrt(N) plan
           steps=_two_phase,
           bound=lambda n, **_: 2 * math.ceil(math.sqrt(max(1, n))) + 1,
           backends=("reference",)),
    OpSpec("template_match", "compute", "§7.6",    # ~M vectorized; paper ~M^2
           steps=lambda m, **_: m, bound=lambda m, **_: m * m, backends=_RP,
           fusable=True),
    OpSpec("stencil", "compute", "§7.3",
           steps=lambda m, **_: m, bound=lambda m, **_: m, backends=_RP,
           fusable=True),
]}

FAMILIES = ("activate", "move", "search", "compare", "compute")


def op_steps(name: str, **sizes) -> int:
    """Concurrent-step count of ``name`` for the given sizes (bound-checked)."""
    return OP_TABLE[name].check(**sizes)


def ops_for_backend(backend: str) -> list[str]:
    return [s.name for s in OP_TABLE.values() if backend in s.backends]


def fusable_ops() -> frozenset[str]:
    """Ops the fusing scheduler may place inside one mega-kernel group."""
    return frozenset(s.name for s in OP_TABLE.values() if s.fusable)
