"""Canonical result conventions shared by every backend.

Historically the reference ops and the Pallas kernels disagreed on details:
substring matches were reported at match *end* addresses (the paper's Fig. 6
carry chain asserts the match line when the last needle item compares), while
``find_all`` spoke in *start* addresses; template match and stencil let
positions run off the row end and wrap (``jnp.roll``), leaving an
implementation-defined tail.

``repro.cpm`` fixes one canonical convention:

  * substring matches are reported at **start** addresses (the address a user
    would index with); the raw end-address view is one documented converter
    away (`starts_to_ends` / `ends_to_starts`).
  * sliding-window ops (template match) report every start whose window fits:
    tail positions ``p > n - m`` are *invalid* and masked (`window_valid`).
  * stencils default to zero padding at the row ends (no wrap); the ring
    (wrapping) view stays available via ``wrap=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ends_to_starts(ends: jax.Array, m: int) -> jax.Array:
    """Convert match-*end* flags to match-*start* flags for an m-item needle.

    A match ending at address ``e`` starts at ``e - (m - 1)``; end flags in
    the first ``m - 1`` addresses cannot be complete matches and are dropped
    (the roll would wrap them into the tail).
    """
    n = ends.shape[-1]
    starts = jnp.roll(ends, -(m - 1), axis=-1)
    return starts & (jnp.arange(n) <= n - m)


def starts_to_ends(starts: jax.Array, m: int) -> jax.Array:
    """Inverse of :func:`ends_to_starts` (start flags -> end flags)."""
    n = starts.shape[-1]
    ends = jnp.roll(starts, m - 1, axis=-1)
    return ends & (jnp.arange(n) >= m - 1)


def window_valid(n: int, m: int, used_len=None) -> jax.Array:
    """Validity flag per start address of an m-item sliding window.

    Position ``p`` is valid iff the whole window lies inside the used region:
    ``p + m <= used_len`` (``used_len`` defaults to the physical length; a
    per-batch vector broadcasts against a trailing address axis).
    """
    used = jnp.asarray(n if used_len is None else used_len)
    return jnp.arange(n) + m <= (used[..., None] if used.ndim else used)


def limit_identity(dtype, mode: str):
    """Identity element of the §7.5 global-limit reduction for ``dtype``.

    The one definition every backend (reference, pallas kernel pad/acc,
    mesh pad) uses for its fill, so the cross-backend bit-identity contract
    cannot be broken by divergent fill conventions.
    """
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        info = jnp.iinfo(dtype)
        return info.min if mode == "max" else info.max
    return -jnp.inf if mode == "max" else jnp.inf


def mask_window_tail(out: jax.Array, m: int, used_len=None, fill=jnp.inf):
    """Mask sliding-window results at invalid tail starts with ``fill``."""
    valid = window_valid(out.shape[-1], m, used_len)
    return jnp.where(valid, out, jnp.asarray(fill, out.dtype))
