"""Content movable memory (paper §4): concurrent in-place range moves.

Every PE can copy its neighbor's addressable register in one cycle (Fig. 5),
so shifting an arbitrary address range left/right is ~1 instruction cycle
regardless of range length.  Insertion, deletion and object grow/shrink are
built from range shifts — the paper's "memory managing itself" (§4.2).

The TPU realization keeps the O(1)-concurrent-step structure: every op below
lowers to a constant number of full-array vector ops (roll + select), never a
serial loop over elements.  These ops are the substrate for in-place KV-cache
management in ``repro.serve.kv_cache``.

All ops work on the last axis; use ``jax.vmap`` for batched layouts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .pe_array import activation_mask


def shift_range(x: jax.Array, start, end, shift: int = 1, fill=None) -> jax.Array:
    """Shift elements whose address lies in [start, end] by ``shift`` places.

    ``shift > 0`` moves content toward higher addresses.  Vacated slots keep
    their old content unless ``fill`` is given.  Content shifted beyond the
    range boundary is dropped (as in hardware, it would overwrite neighbors —
    callers manage the destination range).  O(1) concurrent steps.
    """
    n = x.shape[-1]
    src_mask = activation_mask(n, start, end)            # range being moved
    moved = jnp.roll(x, shift, axis=-1)
    dst_mask = jnp.roll(src_mask, shift)
    if shift > 0:
        dst_mask = dst_mask & (jnp.arange(n) >= shift)
    elif shift < 0:
        dst_mask = dst_mask & (jnp.arange(n) < n + shift)
    out = jnp.where(dst_mask, moved, x)
    if fill is not None:
        vacated = src_mask & ~dst_mask
        out = jnp.where(vacated, fill, out)
    return out


def write_window(x: jax.Array, pos, values: jax.Array) -> jax.Array:
    """Broadcast-write ``values`` into [pos, pos+k): the ~1-cycle write phase
    of insertion.  Shared by :func:`insert` and ``CPMArray.insert`` so the
    §4 semantics exist exactly once."""
    k = values.shape[-1]
    idx = jnp.arange(x.shape[-1])
    in_window = (idx >= pos) & (idx < pos + k)
    # gather the value for each window slot
    vals = values[jnp.clip(idx - pos, 0, k - 1)]
    return jnp.where(in_window, vals, x)


def fill_deleted_tail(x: jax.Array, used_len, k: int, fill=0) -> jax.Array:
    """Fill the ``k`` slots vacated at the tail of the used region after a
    left shift — the cleanup phase of deletion (shared with ``CPMArray``)."""
    idx = jnp.arange(x.shape[-1])
    vacated = (idx >= used_len - k) & (idx < used_len)
    return jnp.where(vacated, fill, x)


def insert(x: jax.Array, pos, values: jax.Array, used_len) -> jax.Array:
    """Insert ``values`` at ``pos``; content in [pos, used_len) shifts right.

    Content beyond the physical end is dropped.  ~1 concurrent step for the
    shift + ~1 for the write, matching the paper's insertion claim.
    """
    out = shift_range(x, pos, used_len - 1, values.shape[-1])
    return write_window(out, pos, values)


def delete(x: jax.Array, pos, k: int, used_len, fill=0) -> jax.Array:
    """Delete ``k`` elements at ``pos``; tail in [pos+k, used_len) shifts left."""
    out = shift_range(x, pos + k, used_len - 1, -k)
    return fill_deleted_tail(out, used_len, k, fill)


def compact(x: jax.Array, keep: jax.Array, fill=0) -> tuple[jax.Array, jax.Array]:
    """Stable compaction: move all kept elements to the front.

    Returns ``(compacted, new_len)``.  The paper performs this as per-object
    range moves; the TPU-native equivalent is a single stable
    cumsum-gather — O(log N) concurrent steps (scan depth), still
    element-count independent.  Used for KV-cache hole removal after
    speculative-decode rejection and sliding-window eviction.
    """
    n = x.shape[-1]
    new_len = jnp.sum(keep.astype(jnp.int32), axis=-1)
    # stable partition permutation: kept elements first, order preserved
    order = jnp.argsort(~keep, axis=-1, stable=True)
    out = jnp.take_along_axis(x, order, axis=-1) if x.ndim == keep.ndim else x[order]
    # mask against the address axis only: a batched (B,) new_len must not
    # broadcast into the batch axis (wrong-and-silent when B == n)
    live = jnp.arange(n) < (new_len[..., None] if new_len.ndim else new_len)
    out = jnp.where(live, out, fill)
    return out, new_len


def move_object(x: jax.Array, src_start, length, dst_start) -> jax.Array:
    """Relocate an object of ``length`` items from src_start to dst_start.

    Single gather per element (constant concurrent steps).  Slots uncovered by
    the move keep their previous content; overlapping moves are handled like
    ``memmove`` (reads happen before writes).
    """
    n = x.shape[-1]
    idx = jnp.arange(n)
    in_dst = (idx >= dst_start) & (idx < dst_start + length)
    src_idx = jnp.clip(idx - dst_start + src_start, 0, n - 1)
    return jnp.where(in_dst, x[..., src_idx] if x.ndim > 1 else x[src_idx], x)
