"""Content searchable memory (paper §5): streaming substring match.

Each PE compares its register against a broadcast ``(datum, mask)`` and ANDs
the result with its *right* neighbor's storage bit (Fig. 6), so matching an
M-item needle takes ~M instruction cycles with no alignment or length limit.

The TPU realization is a ``scan`` over needle positions — one concurrent
compare + one neighbor shift per step, exactly the paper's cycle structure.
Used by ``repro.serve.spec`` for n-gram/draft verification over on-device
token buffers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_eq(hay: jax.Array, datum, mask=None) -> jax.Array:
    """One concurrent compare: (hay & mask) == (datum & mask)."""
    if mask is None:
        return hay == datum
    return (hay & mask) == (jnp.asarray(datum) & mask)


def substring_match(hay: jax.Array, needle: jax.Array,
                    needle_len=None, mask=None) -> jax.Array:
    """Match ``needle`` everywhere in ``hay``; True at match *end* positions.

    Paper §5.1: step 0 matches needle[0] with self-code true; step i>0 ANDs
    the compare of needle[i] with the right-shifted storage bit.  ~M steps.

    ``needle_len`` (optional, dynamic) restricts to a needle prefix so a
    single compiled program serves any needle length <= needle.shape[0].
    """
    m = needle.shape[-1]
    if needle_len is None:
        needle_len = m

    def step(state, i):
        hit = masked_eq(hay, needle[i], mask)
        shifted = jnp.roll(state, 1, axis=-1).at[..., 0].set(False)
        new = jnp.where(i == 0, hit, hit & shifted)
        # steps beyond the live needle leave the storage bits untouched
        return jnp.where(i < needle_len, new, state), None

    init = jnp.zeros(hay.shape, dtype=bool)
    out, _ = jax.lax.scan(step, init, jnp.arange(m))
    return out


def find_all(hay: jax.Array, needle: jax.Array, max_out: int):
    """Start addresses of every occurrence (ascending), via Rule 6."""
    from ..semantics import ends_to_starts
    from .pe_array import enumerate_matches
    ends = substring_match(hay, needle)
    return enumerate_matches(ends_to_starts(ends, needle.shape[-1]), max_out)


def verify_draft(draft: jax.Array, target: jax.Array) -> jax.Array:
    """Speculative-decode acceptance: longest matching prefix length.

    ``draft[i]`` is accepted iff all ``draft[:i+1] == target[:i+1]`` — the
    searchable-memory carry chain applied along the draft. O(log) steps via
    cumulative AND.
    """
    ok = jnp.cumprod((draft == target).astype(jnp.int32), axis=-1)
    return jnp.sum(ok, axis=-1)


def ngram_lookup(context: jax.Array, ngram: jax.Array, max_out: int = 8):
    """Find previous occurrences of the trailing n-gram in the context.

    Prompt-lookup decoding: candidate continuations start right after each
    historical occurrence of the current n-gram.  Returns (starts, valid) of
    the *continuation* positions.
    """
    n = ngram.shape[-1]
    ends = substring_match(context, ngram)
    # continuation begins one past the match end; exclude the trailing self-match
    idx = jnp.arange(context.shape[-1])
    ends = ends & (idx < context.shape[-1] - 1)
    from .pe_array import enumerate_matches
    starts, valid = enumerate_matches(ends, max_out)
    return jnp.where(valid, starts + 1, starts), valid
