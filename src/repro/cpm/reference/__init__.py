"""The pure-`jnp` realization of every paper operation — always available.

These modules are the *reference backend* of `repro.cpm`: the O(1)/O(sqrt N)
concurrent-step structure of the paper lowered to full-array vector ops.
They are also the oracles the Pallas kernels and the mesh collectives are
validated against.  The historical import path ``repro.core.*`` still works
via thin deprecation shims.
"""

from . import comparable, computable, movable, pe_array, searchable

__all__ = ["comparable", "computable", "movable", "pe_array", "searchable"]
