"""Content comparable memory (paper §6): concurrent value comparison.

Every PE compares its masked register against a broadcast datum with one of
{=, !=, <, >, <=, >=} in ~1 cycle; multi-word values compare lexicographically
via the neighbor carry chain (§6.1); M-bin histograms take ~M cycles (§6.3).

Framework use: MoE routing masks and load statistics (``repro.models``),
top-p/top-k sampling thresholds (``repro.serve.sampling``), quantile
calibration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .pe_array import count_matches

_OPS = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "gt": lambda a, b: a > b,
    "le": lambda a, b: a <= b,
    "ge": lambda a, b: a >= b,
}


def compare(x: jax.Array, datum, op: str = "eq", mask=None) -> jax.Array:
    """One concurrent compare of every item against a broadcast datum."""
    if mask is not None:
        x = x & mask
        datum = jnp.asarray(datum) & mask
    return _OPS[op](x, datum)


def lex_compare_lt(words: jax.Array, datum: jax.Array) -> jax.Array:
    """Multi-word ``<`` via the paper's §6.1 carry-chain algorithm.

    ``words``: (..., n_items, n_words) with word significance decreasing
    left-to-right (words[..., 0] most significant).  ``datum``: (n_words,).
    Scans from least to most significant word — ~n_words concurrent steps:
        lt = (w < d) | ((w == d) & lt_from_right)
    """
    n_words = words.shape[-1]

    def step(carry, j):
        w = words[..., j]
        d = datum[j]
        return (w < d) | ((w == d) & carry), None

    init = jnp.zeros(words.shape[:-1], dtype=bool)
    out, _ = jax.lax.scan(step, init, jnp.arange(n_words - 1, -1, -1))
    return out


def histogram(x: jax.Array, edges: jax.Array) -> jax.Array:
    """Paper §6.3: M-section histogram in ~M concurrent count steps.

    ``x``: (..., N) rows; ``edges``: (M+1,) ascending section limits.
    Returns (..., M) per-row counts of items in [edges[i], edges[i+1]).
    Each step is one broadcast compare + one Rule-6 parallel count (the
    count runs over the PE address axis only, so batch rows stay separate).
    """
    def below(e):
        return jnp.sum(compare(x, e, "lt").astype(jnp.int32), axis=-1)

    cum = jax.vmap(below)(edges)        # (M+1, ...) compare+count steps
    return jnp.moveaxis(jnp.diff(cum, axis=0), 0, -1)


def quantile_threshold(x: jax.Array, k, lo, hi, iters: int = 24) -> jax.Array:
    """Smallest t such that count(x > t) < k — bisection over value range.

    Each iteration is one compare + one parallel count (~1 cycle in CPM
    terms); ``iters`` iterations give value resolution (hi-lo)/2**iters.
    Used for top-k/top-p mask construction without a full sort.
    """
    lo = jnp.asarray(lo, dtype=x.dtype)
    hi = jnp.asarray(hi, dtype=x.dtype)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) / 2
        above = count_matches(compare(x, mid, "gt"))
        keep_hi = above >= k            # too many above -> raise threshold
        return jnp.where(keep_hi, mid, lo), jnp.where(keep_hi, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def topk_mask(x: jax.Array, k: int, axis: int = -1) -> jax.Array:
    """Boolean mask of the k largest entries along ``axis``.

    The content-comparable formulation: one threshold lookup + one compare.
    Ties at the threshold are broken by address (first-match priority, R6).
    """
    x = jax.lax.stop_gradient(jnp.moveaxis(x, axis, -1))  # boolean output: no tangent
    kth = -jnp.sort(-x, axis=-1)[..., k - 1 : k]
    gt = x > kth
    eq = x == kth
    need = k - jnp.sum(gt, axis=-1, keepdims=True)
    tie_rank = jnp.cumsum(eq, axis=-1)
    mask = gt | (eq & (tie_rank <= need))
    if axis != -1:
        mask = jnp.moveaxis(mask, -1, axis)
    return mask
