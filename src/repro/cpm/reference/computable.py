"""Content computable memory (paper §7): the general SIMD array algorithms.

Implements, with the paper's concurrent-step structure preserved:
  §7.3  local stencil algebra  (`+` and `#` composition, Eq. 7-2..7-12)
  §7.4  two-phase sectioned sum       ~(M + N/M)  -> ~sqrt(N)
  §7.5  global limit (same pattern)
  §7.6  template matching             ~M^2 (1-D), ~Mx^2*My (2-D), size-free
  §7.7  sorting: odd-even local exchange, defect detection (Fig. 13),
        hybrid local+global ~sqrt(N)
  §7.9  messenger line detection      ~D^2, image-size-free

Every op reports its *concurrent step count* (the paper's instruction-cycle
currency) via the companion ``*_steps`` functions so benchmarks can check the
paper's complexity claims directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# the §7.4/§8 cost models live in the op table — one definition repo-wide
from ..optable import _clog2, optimal_section, two_phase_steps


# ---------------------------------------------------------------------------
# §7.4 / §7.5 — two-phase sectioned global reductions
# ---------------------------------------------------------------------------


def section_sum(x: jax.Array, section: int | None = None) -> jax.Array:
    """Paper §7.4 two-phase sum along the last axis.

    Phase 1: all M-item sections reduce concurrently (ring carry, ~M steps).
    Phase 2: the N/M section sums combine (~N/M steps).
    Lowered as two reductions so XLA sees the same dataflow shape.
    """
    n = x.shape[-1]
    m = section or optimal_section(n)
    pad = (-n) % m
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    sec = x.reshape(*x.shape[:-1], -1, m)
    return jnp.sum(jnp.sum(sec, axis=-1), axis=-1)


def section_sum_steps(n: int, section: int | None = None) -> int:
    return two_phase_steps(n, section)


def section_limit(x: jax.Array, section: int | None = None, mode: str = "max") -> jax.Array:
    """Paper §7.5: global limit with the same two-phase structure."""
    n = x.shape[-1]
    m = section or optimal_section(n)
    pad = (-n) % m
    op = jnp.max if mode == "max" else jnp.min
    if pad:
        from ..semantics import limit_identity
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                    constant_values=limit_identity(x.dtype, mode))
    sec = x.reshape(*x.shape[:-1], -1, m)
    return op(op(sec, axis=-1), axis=-1)


# ---------------------------------------------------------------------------
# §8 — super-connectivity: log-depth combine instead of the serial march
# ---------------------------------------------------------------------------

def tree_combine(parts: jax.Array, combine, identity) -> jax.Array:
    """§8 log-depth pairwise combine along the last axis -> ``(...,)``.

    Level ``j`` (one scan trip = one concurrent instruction cycle) reads the
    partner 2**j places away — the Fig. 16 skip links — so ceil(log2(K))
    trips leave the full combine in element 0.  Lowered as a ``lax.scan``
    over levels so the jaxpr trip count *is* the concurrent-step count the
    op table registers (``benchmarks/run.py cpm_ops`` asserts equality).
    """
    k = parts.shape[-1]
    levels = _clog2(k)
    if levels == 0:
        return parts[..., 0]
    idx = jnp.arange(k)

    def step(x, j):
        stride = jnp.left_shift(1, j)
        partner = jnp.take(x, jnp.clip(idx + stride, 0, k - 1), axis=-1)
        partner = jnp.where(idx + stride < k, partner,
                            jnp.asarray(identity, x.dtype))
        return combine(x, partner), None

    out, _ = jax.lax.scan(step, parts, jnp.arange(levels))
    return out[..., 0]


def super_sum(x: jax.Array, section: int | None = None) -> jax.Array:
    """§8 super-connected global sum along the last axis.

    Phase 1: log-depth tree inside every M-item section; phase 2: log-depth
    tree over the N/M section partials — ~log2(M) + log2(N/M) ~ log2(N)
    concurrent steps, vs the §7.4 two-phase ~2·√N.  Same value as
    :func:`section_sum` (bit-identical for ints).
    """
    # match jnp.sum accumulation semantics (ints promote to int32)
    x = x.astype(jnp.zeros((), x.dtype).sum().dtype)
    n = x.shape[-1]
    m = section or optimal_section(n)
    pad = (-n) % m
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    sec = x.reshape(*x.shape[:-1], -1, m)
    partials = tree_combine(sec, jnp.add, 0)          # phase 1: clog2(M)
    return tree_combine(partials, jnp.add, 0)         # phase 2: clog2(N/M)


def super_limit(x: jax.Array, section: int | None = None,
                mode: str = "max") -> jax.Array:
    """§8 super-connected global max/min (log-depth two-phase)."""
    from ..semantics import limit_identity

    identity = limit_identity(x.dtype, mode)
    combine = jnp.maximum if mode == "max" else jnp.minimum
    n = x.shape[-1]
    m = section or optimal_section(n)
    pad = (-n) % m
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                    constant_values=identity)
    sec = x.reshape(*x.shape[:-1], -1, m)
    partials = tree_combine(sec, combine, identity)
    return tree_combine(partials, combine, identity)


def section_sum_2d(x: jax.Array, mx: int | None = None, my: int | None = None) -> jax.Array:
    """Paper §7.4 2-D sum: row phase, column phase, serial section scan.

    Optimal at Mx ~ My ~ cbrt(Nx*Ny): total ~(Mx + My + Nx/Mx * Ny/My).
    """
    ny, nx = x.shape[-2], x.shape[-1]
    m = max(1, round((nx * ny) ** (1.0 / 3.0)))
    mx = mx or m
    my = my or m
    px, py = (-nx) % mx, (-ny) % my
    if px or py:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, py), (0, px)])
    sec = x.reshape(*x.shape[:-2], x.shape[-2] // my, my, x.shape[-1] // mx, mx)
    return jnp.sum(sec, axis=(-3, -2, -1)).sum(axis=-1)


# ---------------------------------------------------------------------------
# §7.3 — local stencil algebra
# ---------------------------------------------------------------------------

def compose_taps(a, b):
    """The ``#`` operator (Eq. 7-6): applying A then B == conv(A, B)."""
    return np.convolve(np.asarray(a), np.asarray(b))


def add_taps(a, b):
    """The ``+`` operator (Eq. 7-3): center-aligned tap addition."""
    a, b = np.asarray(a), np.asarray(b)
    n = max(a.shape[0], b.shape[0])
    pa, pb = (n - a.shape[0]) // 2, (n - b.shape[0]) // 2
    return np.pad(a, (pa, pa)) + np.pad(b, (pb, pb))


def stencil_1d(x: jax.Array, taps, wrap: bool = True) -> jax.Array:
    """Apply an odd-length tap vector by M neighbor-shift accumulations.

    Index convention matches §7.3: taps[center + k] weights the neighbor k
    places to the *left* (lower address) being accumulated into each PE, i.e.
    (1 0 0) denotes the content of the left layer.

    ``wrap=True`` treats the row as a ring (historical behavior);
    ``wrap=False`` zero-pads past the row ends — the canonical `repro.cpm`
    convention, matching the Pallas kernel's ``wrap=`` flag.
    """
    taps = np.asarray(taps)
    n = x.shape[-1]
    idx = jnp.arange(n)
    c = taps.shape[0] // 2
    out = jnp.zeros_like(x, dtype=jnp.result_type(x.dtype, jnp.float32)
                         if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype)
    for k in range(-c, c + 1):          # ~M concurrent shift+multiply-add steps
        w = taps[c + k]
        if w == 0:
            continue
        shifted = jnp.roll(x, k, axis=-1)
        if not wrap:                    # drop contributions that wrapped
            if k > 0:
                shifted = jnp.where(idx >= k, shifted, 0)
            elif k < 0:
                shifted = jnp.where(idx < n + k, shifted, 0)
        out = out + w * shifted
    return out


def stencil_2d(x: jax.Array, taps2d) -> jax.Array:
    """2-D stencil via neighbor shifts (square lattice, §7.1)."""
    taps2d = np.asarray(taps2d)
    cy, cx = taps2d.shape[0] // 2, taps2d.shape[1] // 2
    out = jnp.zeros_like(x, dtype=jnp.result_type(x.dtype, jnp.float32)
                         if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype)
    for dy in range(-cy, cy + 1):
        for dx in range(-cx, cx + 1):
            w = taps2d[cy + dy, cx + dx]
            if w == 0:
                continue
            out = out + w * jnp.roll(jnp.roll(x, dy, axis=-2), dx, axis=-1)
    return out


# ---------------------------------------------------------------------------
# §7.7 — sorting
# ---------------------------------------------------------------------------

def count_disorder(x: jax.Array, descending: bool = False) -> jax.Array:
    """Rule 6 applied to sorting: # of neighbors violating the order."""
    a, b = x[..., :-1], x[..., 1:]
    bad = (a > b) if not descending else (a < b)
    return jnp.sum(bad.astype(jnp.int32), axis=-1)


def odd_even_step(x: jax.Array, odd_phase) -> jax.Array:
    """One concurrent compare-exchange of all (even,odd) or (odd,even) pairs.

    ~1 instruction cycle in the paper; one vector min/max + select here.
    """
    n = x.shape[-1]
    idx = jnp.arange(n)
    odd_phase = jnp.asarray(odd_phase)
    is_left = (idx % 2) == (odd_phase % 2)
    partner = jnp.clip(jnp.where(is_left, idx + 1, idx - 1), 0, n - 1)
    px = jnp.take(x, partner, axis=-1)
    lo = jnp.minimum(x, px)
    hi = jnp.maximum(x, px)
    out = jnp.where(is_left, lo, hi)
    # boundary PEs without a partner keep their value
    solo = (partner == idx) | (is_left & (idx == n - 1))
    return jnp.where(solo, x, out)


def odd_even_sort(x: jax.Array, steps: int | None = None) -> jax.Array:
    """Local-exchange sort: ``steps`` alternating odd/even exchange cycles.

    Full sort needs N steps; the hybrid algorithm (below) stops at ~sqrt(N).
    """
    n = x.shape[-1]
    steps = n if steps is None else steps

    def body(i, x):
        return odd_even_step(x, i % 2)

    return jax.lax.fori_loop(0, steps, body, x)


def detect_defects(x: jax.Array) -> dict[str, jax.Array]:
    """Fig. 13 point-defect classification in each neighborhood (~4 cycles).

    peak:  x[i] > both neighbors;  valley: x[i] < both neighbors;
    fault: an exchanged adjacent pair inside otherwise sorted context.
    """
    left = jnp.roll(x, 1, axis=-1).at[..., 0].set(-jnp.inf)
    right = jnp.roll(x, -1, axis=-1).at[..., -1].set(jnp.inf)
    peak = (x > left) & (x > right)
    valley = (x < left) & (x < right)
    r2 = jnp.roll(x, -2, axis=-1).at[..., -2:].set(jnp.inf)
    l2 = jnp.roll(x, 2, axis=-1).at[..., :2].set(-jnp.inf)
    fault = (x > right) & (x <= r2) & (right >= left) & (l2 <= right)
    return {"peak": peak & ~fault, "valley": valley & ~fault, "fault": fault}


def hybrid_sort(x: jax.Array, local_steps: int | None = None) -> jax.Array:
    """Paper §7.7 ~sqrt(N) strategy: local exchange then global defect moves.

    Phase 1: ~sqrt(N) odd-even cycles leave ~sqrt(N)-spaced point defects.
    Phase 2: global move — each round concurrently detects defects (R6) and
    inserts the worst remaining peak/valley at its destination via movable-
    memory range shifts (~2 cycles each); loops until the disorder counter
    reads zero.  A while_loop bounds phase 2 by the remaining disorder.
    """
    from .movable import insert, delete

    n = x.shape[-1]
    m = local_steps or optimal_section(n)
    x = odd_even_sort(x, m)

    def fix_one(x):
        # faults fix concurrently by one exchange step pair (~2 cycles)
        x = odd_even_step(odd_even_step(x, 0), 1)
        d = detect_defects(x)
        any_defect = d["peak"] | d["valley"]
        idx = jnp.where(any_defect, jnp.arange(n), n)
        pos = jnp.min(idx)

        def move(x):
            p = jnp.minimum(pos, n - 1)
            v = x[p]
            is_peak = d["peak"][p]
            # remove the defect, then insert at its sorted destination
            removed = delete(x, pos, 1, n,
                             fill=jnp.where(is_peak, x.dtype.type(jnp.inf),
                                            x.dtype.type(-jnp.inf))
                             if jnp.issubdtype(x.dtype, jnp.floating) else 0)
            dest = jnp.sum((removed[: n - 1] < v).astype(jnp.int32))
            return insert(removed, dest, v[None], n)

        return jax.lax.cond(pos < n, move, lambda x: x, x)

    def cond(x):
        return count_disorder(x) > 0

    def body(x):
        return fix_one(x)

    return jax.lax.while_loop(cond, body, x)


def hybrid_sort_steps(n: int) -> int:
    return two_phase_steps(n)


# ---------------------------------------------------------------------------
# §7.6 — template matching (SAD over all alignments)
# ---------------------------------------------------------------------------

def template_match_1d(data: jax.Array, template: jax.Array) -> jax.Array:
    """SAD of the template at every start position (~M concurrent steps here;
    ~M^2 in the paper's section-local schedule — both image-size-free).

    Output o[p] = sum_j |data[p+j] - template[j]|, positions running off the
    end wrap (callers mask the tail).
    """
    m = template.shape[-1]

    def step(acc, j):
        shifted = jnp.roll(data, -j, axis=-1)
        return acc + jnp.abs(shifted - template[j]), None

    acc = jnp.zeros(data.shape, dtype=jnp.result_type(data.dtype, jnp.float32)
                    if jnp.issubdtype(data.dtype, jnp.integer) else data.dtype)
    out, _ = jax.lax.scan(step, acc, jnp.arange(m))
    return out


def template_match_2d(data: jax.Array, template: jax.Array) -> jax.Array:
    """2-D SAD at every (y, x) start position (wrapping tail)."""
    my, mx = template.shape[-2], template.shape[-1]

    def step(acc, ji):
        j, i = ji // mx, ji % mx
        shifted = jnp.roll(jnp.roll(data, -j, axis=-2), -i, axis=-1)
        return acc + jnp.abs(shifted - template[j, i]), None

    acc = jnp.zeros(data.shape, dtype=jnp.result_type(data.dtype, jnp.float32)
                    if jnp.issubdtype(data.dtype, jnp.integer) else data.dtype)
    out, _ = jax.lax.scan(step, acc, jnp.arange(my * mx))
    return out


# ---------------------------------------------------------------------------
# §7.9 — messenger line detection
# ---------------------------------------------------------------------------

def line_segment_value(img: jax.Array, mx: int, my: int) -> jax.Array:
    """Messenger accumulation for slope my/mx (Fig. 14), all pixels at once.

    A messenger walks (mx+my) steps from the far corner of each pixel's
    (mx x my) area back to the pixel, adding intensities left of the ideal
    line and subtracting those right of it.  ~(mx+my) concurrent steps,
    image-size independent.
    """
    steps = []
    x, y = mx, my
    # Bresenham-style walk from (mx, my) to (0, 0)
    while x > 0 or y > 0:
        if x * my >= y * mx and x > 0:
            x -= 1
            steps.append((0, 1))       # step left in x: roll +1 in axis -1
        else:
            y -= 1
            steps.append((1, 0))
        # sign: pixels below the ideal line add, above subtract
    acc = jnp.zeros(img.shape, dtype=jnp.float32)
    px, py = mx, my
    for dy, dx in steps:
        side = 1.0 if px * my - py * mx >= 0 else -1.0
        contrib = jnp.roll(jnp.roll(img, -py, axis=-2), -px, axis=-1)
        acc = acc + side * contrib
        px, py = px - dx, py - dy
    return acc


def edge_along_x(img: jax.Array, length: int) -> jax.Array:
    """§7.9 axis-aligned edge detector: vertical gradient, L-neighbor sum."""
    grad = jnp.roll(img, -1, axis=-2) - jnp.roll(img, 1, axis=-2)
    taps = np.ones(2 * length + 1)
    taps[:length] = 0                   # only the L left neighbors + self
    return stencil_1d(grad, taps)
