"""Rules 4 & 6 of the CPM paper: PE activation and self-identification.

The paper's *general decoder* (§3.3) activates every PE whose element address
``a`` satisfies::

    start <= a <= end   and   (a - start) % carry == 0          (Rule 4)

in ~1 instruction cycle, by composing (1) a carry-pattern generator,
(2) a parallel shifter and (3) an all-line decoder.  On TPU the decoder is a
vectorized predicate over an iota — also O(1).  Both the fused predicate and
the paper's three-stage decomposition are provided; tests assert equivalence.

Rule 6 (match line -> priority encoder / parallel counter) becomes global
predicate reductions: ``count_matches`` (parallel counter), ``first_match``
(priority encoder), ``enumerate_matches``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Rule 4 — the general decoder
# ---------------------------------------------------------------------------

def activation_mask(n: int, start, end, carry=1) -> jax.Array:
    """Fused general decoder: O(1) boolean activation mask of length ``n``."""
    addr = jnp.arange(n)
    start = jnp.asarray(start)
    end = jnp.asarray(end)
    carry = jnp.maximum(jnp.asarray(carry), 1)
    return (addr >= start) & (addr <= end) & ((addr - start) % carry == 0)


def carry_pattern(n: int, carry) -> jax.Array:
    """Paper Eq. 3-1: assert every address that is a multiple of ``carry``.

    D[0] is always asserted; D[a] is asserted iff a % carry == 0.
    """
    addr = jnp.arange(n)
    carry = jnp.maximum(jnp.asarray(carry), 1)
    return addr % carry == 0


def parallel_shift(bits: jax.Array, shift) -> jax.Array:
    """Paper Eq. 3-2 / Fig. 2: H[a] = D[a - s] if a >= s else 0.

    Implemented as the paper does — an accumulative barrel shifter over the
    binary digits of ``shift`` (each digit shifts by 2**j) — expressed with a
    scan so the lowering matches the log-depth hardware structure.
    """
    n = bits.shape[0]
    nbits = max(1, (n - 1).bit_length())
    shift = jnp.asarray(shift)

    def stage(h, j):
        take = (shift >> j) & 1
        shifted = jnp.roll(h, 1 << j)
        # zero the wrapped-around low addresses
        shifted = jnp.where(jnp.arange(n) < (1 << j), False, shifted)
        return jnp.where(take == 1, shifted, h), None

    out, _ = jax.lax.scan(stage, bits, jnp.arange(nbits))
    return out


def all_line(n: int, end) -> jax.Array:
    """Paper Eq. 3-3 / Fig. 3: assert every address <= ``end``."""
    return jnp.arange(n) <= jnp.asarray(end)


def general_decoder(n: int, start, end, carry=1) -> jax.Array:
    """Paper §3.3 three-stage decoder: carry pattern -> shift -> all-line AND."""
    return parallel_shift(carry_pattern(n, carry), start) & all_line(n, end)


# ---------------------------------------------------------------------------
# Rule 6 — match line, parallel counter, priority encoder
# ---------------------------------------------------------------------------

def count_matches(match: jax.Array) -> jax.Array:
    """Parallel counter: number of asserted match lines (any shape)."""
    return jnp.sum(match.astype(jnp.int32))


def any_match(match: jax.Array) -> jax.Array:
    return jnp.any(match)


def first_match(match: jax.Array) -> jax.Array:
    """Priority encoder: lowest asserted address, or n if none asserted."""
    n = match.shape[-1]
    idx = jnp.where(match, jnp.arange(n), n)
    return jnp.min(idx, axis=-1)


def enumerate_matches(match: jax.Array, max_out: int) -> tuple[jax.Array, jax.Array]:
    """Materialize up to ``max_out`` asserted addresses in ascending order.

    Returns ``(indices, valid)`` of shape ``(..., max_out)``; unused slots
    hold ``n``.  Replaces the paper's serial priority-encoder drain with a
    single sort — on TPU the one-shot materialization is cheaper than a
    serial drain.  The slice runs along the *address* axis (batched
    ``(B, n)`` match lines keep their batch axis and per-row ``max_out``
    truncation).
    """
    n = match.shape[-1]
    keyed = jnp.where(match, jnp.arange(n), n)
    ordered = jnp.sort(keyed, axis=-1)[..., :max_out]
    return ordered, ordered < n
