"""`CPMBank` — one fixed-shape array of CPM sub-pages.

A bank is the pool's unit of physical residency: a batched ``(slots, width)``
:class:`~repro.cpm.array.CPMArray` whose rows are *sub-pages* handed out by
the allocator and whose per-row ``used_len`` registers are the §4.2 "memory
managing itself" length state.  Under the serving pool's paged layout the
rows are ``(pages_per_bank, page_size)`` fixed-size sub-pages: a session's
logical token row is its ordered page list's rows concatenated, each
sub-page's length register holding how much of it is live (full pages
``page_size``, the tail page the remainder).  The degenerate
``page_size == max_len`` configuration makes every row a whole session —
the pre-paging layout, still what standalone tests build.  The bank owns
the buffers; callers get transient ``CPMArray`` views (:meth:`device`) to
run programs against and write the result back with :meth:`update` — the
bank never copies rows to run an instruction stream, only to move
sub-pages in or out.

Sub-page movement is the one place rows do travel, and it goes through the
paged-row kernels (`repro.kernels.cpm_kernels.gather_rows` /
``scatter_rows``) on the pallas backend — dynamic page indices ride in
scalar-prefetch so each sub-page is ONE (1, width) DMA — with a plain jnp
take/scatter realization on reference, differential-tested identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..array import CPMArray


class CPMBank:
    """A ``(slots, width)`` bank of pages with per-page length registers."""

    def __init__(self, slots: int, width: int, dtype=jnp.int32,
                 backend: str = "reference", interpret: bool | None = None):
        if slots <= 0 or width <= 0:
            raise ValueError(f"bank needs slots>0, width>0; got "
                             f"({slots}, {width})")
        self.slots = slots
        self.width = width
        self.backend = backend
        self.interpret = interpret
        self.data = jnp.zeros((slots, width), dtype)
        self.lens = jnp.zeros((slots,), jnp.int32)

    @property
    def dtype(self):
        return self.data.dtype

    # -- CPMArray views -----------------------------------------------------
    def device(self) -> CPMArray:
        """The bank as a batched CPM device (for program execution)."""
        return CPMArray(self.data, self.lens, self.backend, self.interpret)

    def update(self, arr: CPMArray) -> None:
        """Adopt the state a program run left behind."""
        if arr.data.shape != (self.slots, self.width):
            raise ValueError(f"bank is {(self.slots, self.width)}, "
                             f"got {arr.data.shape}")
        self.data = arr.data
        self.lens = jnp.broadcast_to(jnp.asarray(arr.used_len, jnp.int32),
                                     (self.slots,))

    # -- single-page access ---------------------------------------------------
    def write_row(self, slot: int, values, length=None) -> None:
        """Place a page: ``values`` (padded to ``width``) becomes row
        ``slot``, its length register becomes ``length`` (default: the
        value count).  The whole row is replaced — stale content from the
        page's previous tenant cannot leak past the new ``used_len``."""
        values = jnp.asarray(values, self.dtype).reshape(-1)
        k = values.shape[0]
        if k > self.width:
            raise ValueError(f"row of {k} items exceeds bank width "
                             f"{self.width}")
        row = jnp.zeros((self.width,), self.dtype).at[:k].set(values)
        self.scatter(jnp.asarray([slot], jnp.int32), row[None],
                     jnp.asarray([k if length is None else length],
                                 jnp.int32))

    def read_row(self, slot: int) -> tuple[np.ndarray, int]:
        """One page out (host copy): ``(row (width,), used length)``."""
        row = np.asarray(self.gather(jnp.asarray([slot], jnp.int32))[0])
        return row, int(self.lens[slot])

    def clear_row(self, slot: int) -> None:
        self.write_row(slot, jnp.zeros((0,), self.dtype), 0)

    # -- paged movement -------------------------------------------------------
    def _pallas_interpret(self) -> bool:
        """The canonical interpret-default policy, resolved once by
        ``PallasBackend`` (compiled on TPU, interpreter elsewhere)."""
        from .. import backends
        return backends.get_backend("pallas",
                                    interpret=self.interpret).interpret

    def gather(self, idx) -> jax.Array:
        """Rows at ``idx`` (K,) -> (K, width), via the scalar-prefetch DMA
        kernel on pallas, jnp take on reference."""
        idx = jnp.asarray(idx, jnp.int32)
        if self.backend == "pallas":
            from repro.kernels import cpm_kernels as K
            return K.gather_rows(self.data, idx,
                                 interpret=self._pallas_interpret())
        return jnp.take(self.data, idx, axis=0)

    def scatter(self, idx, rows, lens) -> None:
        """Write ``rows`` (K, width) into pages ``idx`` (K unique slots) and
        set their length registers to ``lens`` (K,)."""
        idx = jnp.asarray(idx, jnp.int32)
        rows = jnp.asarray(rows, self.dtype)
        if self.backend == "pallas":
            from repro.kernels import cpm_kernels as K
            self.data = K.scatter_rows(self.data, idx, rows,
                                       interpret=self._pallas_interpret())
        else:
            self.data = self.data.at[idx].set(rows)
        self.lens = self.lens.at[idx].set(jnp.asarray(lens, jnp.int32))
