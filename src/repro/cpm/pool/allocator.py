"""The self-managing page-table allocator: CPM bookkeeping for CPM banks.

The paper's §4.2 pitch is a memory that manages itself; the associative-
processor literature (arXiv:2203.00662) pushes the same idea one level up —
use the memory's *own* content-addressable ops for its bookkeeping.  This
allocator does exactly that: slot metadata (state code, last-use tick) lives
in ``CPMArray`` devices, and every query is a paper op —

  * free-slot lookup   = §6.1 broadcast ``compare(FREE)`` + Rule-6
                         priority-encoder drain (``enumerate_matches``);
  * LRU victim lookup  = §7.5 ``global_limit("min")`` over the masked tick
                         file, then one more compare to address the holder;
  * occupancy counters = §6 compare + Rule-6 ``count``;
  * reclamation        = §4.2 ``compact`` packing the used slot ids.

Writes (alloc/free/touch) are single-address broadcast writes — activate one
slot, write one word — mutated through ``.at[slot].set`` on the metadata
buffers.  The host only ever sees slot *numbers*; the search work happens in
the memory.  A pure-Python oracle with identical semantics lives in
:class:`OracleAllocator` for the property-test suite.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..array import CPMArray
from ..reference import pe_array

FREE = 0
USED = 1

_NO_TICK = jnp.iinfo(jnp.int32).max


class SlotAllocator:
    """Page-table allocator over ``n_slots`` sessions of one pool, plus an
    optional file of ``n_pages`` *sub-pages* with per-session page lists.

    ``backend``/``interpret`` route the metadata queries like any other
    ``CPMArray`` (reference by default; pallas for kernel-resident
    metadata).  All methods are host-synchronous by design — allocation is
    admission control, a host decision — but each decision costs O(1)
    concurrent CPM steps, not a host-side scan over slots.

    With ``n_pages > 0`` the allocator also owns the sub-page metadata
    file: :meth:`alloc_pages` claims the ``k`` lowest free pages of a
    bank's range in ONE §6.1 broadcast compare + Rule-6 drain
    (``enumerate_matches(max_out=k)``), all-or-nothing; the ordered page
    list rides on the owning slot and :meth:`free` releases slot and
    pages together, so a retire or cancel can never leak a sub-page.
    """

    def __init__(self, n_slots: int, backend: str = "reference",
                 interpret: bool | None = None, n_pages: int = 0):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        if n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {n_pages}")
        self.n_slots = n_slots
        self.n_pages = n_pages
        self._backend = backend
        self._interpret = interpret
        self._state = jnp.full((n_slots,), FREE, jnp.int32)
        self._tick = jnp.zeros((n_slots,), jnp.int32)
        self._clock = 0
        # sub-page metadata file + host mirror of the ordered page lists
        self._pstate = jnp.full((max(n_pages, 1),), FREE, jnp.int32)
        self._pages: dict[int, list[int]] = {}

    # -- CPMArray views of the metadata file --------------------------------
    def _dev(self, data) -> CPMArray:
        return CPMArray(data, jnp.asarray(self.n_slots, jnp.int32),
                        self._backend, self._interpret)

    def _pdev(self, data) -> CPMArray:
        return CPMArray(data, jnp.asarray(self.n_pages, jnp.int32),
                        self._backend, self._interpret)

    # -- queries (all CPM ops) ----------------------------------------------
    def free_count(self) -> int:
        return int(self._dev(self._state).count(FREE))

    def used_count(self) -> int:
        return int(self._dev(self._state).count(USED))

    def is_free(self, slot: int) -> bool:
        self._check(slot)
        return int(self._state[slot]) == FREE

    def alloc(self) -> int | None:
        """Claim the lowest free page, or ``None`` when the pool is full.

        One §6.1 broadcast compare asserts every free slot's match line
        concurrently; the Rule-6 drain materializes the lowest address."""
        flags = self._dev(self._state).compare(FREE)
        addrs, valid = pe_array.enumerate_matches(flags, max_out=1)
        if not bool(valid[0]):
            return None
        slot = int(addrs[0])
        self._state = self._state.at[slot].set(USED)
        self._pages[slot] = []
        self.touch(slot)
        return slot

    # -- sub-page file (CPM ops on the page metadata device) ----------------
    def _prange(self, lo: int, hi: int | None) -> tuple[int, int]:
        hi = self.n_pages if hi is None else hi
        if not 0 <= lo <= hi <= self.n_pages:
            raise IndexError(f"page range [{lo}, {hi}) outside "
                             f"[0, {self.n_pages})")
        return lo, hi

    def page_free_count(self, lo: int = 0, hi: int | None = None) -> int:
        """Free sub-pages within ``[lo, hi)`` (a bank's range): one §6
        broadcast compare, Rule-6 count of the masked match lines."""
        if not self.n_pages:
            return 0
        lo, hi = self._prange(lo, hi)
        flags = self._pdev(self._pstate).compare(FREE)
        ids = jnp.arange(self.n_pages, dtype=jnp.int32)
        return int(pe_array.count_matches(flags & (ids >= lo) & (ids < hi)))

    def alloc_pages(self, slot: int, k: int, lo: int = 0,
                    hi: int | None = None) -> list[int] | None:
        """Grow ``slot``'s page list by the ``k`` lowest free sub-pages in
        ``[lo, hi)``, or ``None`` (nothing claimed) when fewer than ``k``
        are free — all-or-nothing, so a mid-decode top-up either fully
        covers the next chunk or parks the session.

        One §6.1 broadcast ``compare(FREE)`` (range-masked) asserts every
        candidate's match line; the Rule-6 priority-encoder drain
        (``enumerate_matches(max_out=k)``) materializes the ``k`` lowest
        addresses."""
        self._check(slot)
        if int(self._state[slot]) != USED:
            raise ValueError(f"slot {slot} is free; pages need an owner")
        if k <= 0:
            raise ValueError(f"page count must be positive, got {k}")
        lo, hi = self._prange(lo, hi)
        flags = self._pdev(self._pstate).compare(FREE)
        ids = jnp.arange(self.n_pages, dtype=jnp.int32)
        addrs, valid = pe_array.enumerate_matches(
            flags & (ids >= lo) & (ids < hi), max_out=k)
        if not bool(valid.all()):
            return None
        got = [int(a) for a in np.asarray(addrs)]
        self._pstate = self._pstate.at[jnp.asarray(got)].set(USED)
        self._pages.setdefault(slot, []).extend(got)
        return got

    def pages(self, slot: int) -> list[int]:
        """``slot``'s ordered page list (logical rank -> sub-page id)."""
        self._check(slot)
        return list(self._pages.get(slot, []))

    def victim(self) -> int | None:
        """The least-recently-used *used* page (LRU eviction candidate).

        §7.5 ``global_limit("min")`` over the tick file (free slots masked
        to the identity), then one compare to address the minimum's
        holder.  ``None`` when nothing is allocated."""
        used = self._dev(self._state).compare(USED)
        if not bool(pe_array.any_match(used)):
            return None
        masked = jnp.where(used, self._tick, _NO_TICK)
        oldest = self._dev(masked).global_limit("min")
        hits = self._dev(masked).compare(oldest)
        addrs, _ = pe_array.enumerate_matches(hits & used, max_out=1)
        return int(addrs[0])

    def used_slots(self) -> list[int]:
        """Used page ids packed to the front — the §4.2 ``compact`` of the
        slot-id file under the used flags (the reclamation/packing query
        the serving pool gathers live rows with)."""
        used = self._dev(self._state).compare(USED)
        ids = self._dev(jnp.arange(self.n_slots, dtype=jnp.int32))
        packed = ids.compact(used, fill=-1)
        k = int(packed.used_len)
        return [int(v) for v in np.asarray(packed.data[:k])]

    # -- transitions (single-address broadcast writes) ----------------------
    def free(self, slot: int) -> None:
        """Release ``slot`` AND its whole page list — retire, cancel and
        park all come through here, so sub-pages cannot leak."""
        self._check(slot)
        if int(self._state[slot]) != USED:
            raise ValueError(f"double free of slot {slot}")
        self._state = self._state.at[slot].set(FREE)
        held = self._pages.pop(slot, [])
        if held:
            self._pstate = self._pstate.at[jnp.asarray(held)].set(FREE)

    def touch(self, slot: int) -> None:
        """Stamp ``slot`` as most recently used (LRU bookkeeping)."""
        self._check(slot)
        self._clock += 1
        self._tick = self._tick.at[slot].set(self._clock)

    def _check(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} out of range [0, {self.n_slots})")

    # -- test hooks ---------------------------------------------------------
    def state_vector(self) -> np.ndarray:
        return np.asarray(self._state)

    def page_state_vector(self) -> np.ndarray:
        return np.asarray(self._pstate[:self.n_pages])


class OracleAllocator:
    """Naive host-side allocator with identical semantics — the property
    tests' differential oracle (no CPM ops, just Python)."""

    def __init__(self, n_slots: int, n_pages: int = 0):
        self.n_slots = n_slots
        self.n_pages = n_pages
        self.used: dict[int, int] = {}          # slot -> last-use tick
        self.page_lists: dict[int, list[int]] = {}   # slot -> ordered pages
        self.page_owner: dict[int, int] = {}         # page -> slot
        self._clock = 0

    def alloc(self) -> int | None:
        for s in range(self.n_slots):
            if s not in self.used:
                self._clock += 1
                self.used[s] = self._clock
                self.page_lists[s] = []
                return s
        return None

    def free(self, slot: int) -> None:
        del self.used[slot]
        for p in self.page_lists.pop(slot, []):
            del self.page_owner[p]

    def touch(self, slot: int) -> None:
        self._clock += 1
        self.used[slot] = self._clock

    def victim(self) -> int | None:
        if not self.used:
            return None
        oldest = min(self.used.values())
        return min(s for s, t in self.used.items() if t == oldest)

    def free_count(self) -> int:
        return self.n_slots - len(self.used)

    def used_slots(self) -> list[int]:
        return sorted(self.used)

    # -- sub-page file ------------------------------------------------------
    def alloc_pages(self, slot: int, k: int, lo: int = 0,
                    hi: int | None = None) -> list[int] | None:
        hi = self.n_pages if hi is None else hi
        got = [p for p in range(lo, hi) if p not in self.page_owner][:k]
        if len(got) < k:
            return None
        for p in got:
            self.page_owner[p] = slot
        self.page_lists.setdefault(slot, []).extend(got)
        return got

    def pages(self, slot: int) -> list[int]:
        return list(self.page_lists.get(slot, []))

    def page_free_count(self, lo: int = 0, hi: int | None = None) -> int:
        hi = self.n_pages if hi is None else hi
        return sum(1 for p in range(lo, hi) if p not in self.page_owner)
