"""Host-side session registry for the pool: lifecycle, placement, FIFO.

Sessions are the pool's unit of admission: a prompt plus a token budget,
moving ``WAITING -> ACTIVE -> DONE``.  The table is deliberately plain
Python — placement decisions are host decisions — while everything the
sessions *own* (token pages, KV rows, slot metadata) lives device-side in
the banks and the allocator.  The table never touches device memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any

WAITING = "waiting"
ACTIVE = "active"
DONE = "done"


@dataclasses.dataclass
class Session:
    sid: int
    prompt: Any                        # (s,) int32 tokens (device or host)
    prompt_len: int
    budget: int                        # max new tokens (incl. the prefill one)
    phase: str = WAITING
    bank: int = -1                     # placement, valid while ACTIVE
    slot: int = -1                     # global slot id
    emitted: int = 0
    tokens: Any = None                 # final (s + emitted,) output when DONE

    @property
    def finished(self) -> bool:
        return self.emitted >= self.budget


class SessionTable:
    """FIFO admission queue + slot-indexed lookup of active sessions."""

    def __init__(self):
        self._sessions: dict[int, Session] = {}
        self._queue: list[int] = []               # WAITING, arrival order
        self._by_slot: dict[int, int] = {}        # global slot -> sid
        self._next = 0

    def __len__(self):
        return len(self._sessions)

    def add(self, prompt, prompt_len: int, budget: int) -> Session:
        s = Session(self._next, prompt, prompt_len, budget)
        self._next += 1
        self._sessions[s.sid] = s
        self._queue.append(s.sid)
        return s

    def get(self, sid: int) -> Session:
        return self._sessions[sid]

    def next_waiting(self) -> Session | None:
        return self._sessions[self._queue[0]] if self._queue else None

    def activate(self, sid: int, bank: int, slot: int) -> Session:
        s = self._sessions[sid]
        assert s.phase == WAITING and self._queue[0] == sid, \
            f"session {sid} is not the queue head"
        self._queue.pop(0)
        s.phase, s.bank, s.slot = ACTIVE, bank, slot
        self._by_slot[slot] = sid
        return s

    def at_slot(self, slot: int) -> Session | None:
        sid = self._by_slot.get(slot)
        return self._sessions[sid] if sid is not None else None

    def finish(self, sid: int, tokens) -> Session:
        s = self._sessions[sid]
        if s.phase == ACTIVE:
            del self._by_slot[s.slot]
        elif s.phase == WAITING:                  # zero-budget fast path
            self._queue.remove(sid)
        s.phase, s.tokens = DONE, tokens
        return s

    def active(self) -> list[Session]:
        return [self._sessions[sid] for sid in sorted(self._by_slot.values())]

    def waiting_count(self) -> int:
        return len(self._queue)

    def active_count(self) -> int:
        return len(self._by_slot)

    def all_done(self) -> bool:
        return not self._queue and not self._by_slot

    def outputs(self) -> dict[int, Any]:
        """Non-destructive view of every DONE session's tokens."""
        return {sid: s.tokens for sid, s in self._sessions.items()
                if s.phase == DONE}

    def collect_finished(self) -> dict[int, Any]:
        """Outputs of sessions finished since the last collection; the
        collected sessions are evicted from the table, so a long-running
        service's memory stays bounded and a later collection never
        re-delivers an old result."""
        done = [sid for sid, s in self._sessions.items() if s.phase == DONE]
        return {sid: self._sessions.pop(sid).tokens for sid in done}
