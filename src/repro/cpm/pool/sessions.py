"""Host-side session registry for the pool: lifecycle, placement, FIFO.

Sessions are the pool's unit of admission: a prompt plus a token budget,
moving ``WAITING -> ACTIVE -> DONE`` — with a ``PARKED`` detour when the
serving gateway preempts an active session (its pages are saved to a
host-side parking buffer and the session re-queues FIFO for a later
restore; see ``repro.serve.gateway.preempt``).  The table is deliberately
plain Python — placement decisions are host decisions — while everything
the sessions *own* (token pages, KV rows, slot metadata) lives device-side
in the banks and the allocator.  The table never touches device memory
(a parked session's page image is held by the session object, not the
table).
"""

from __future__ import annotations

import dataclasses
from typing import Any

WAITING = "waiting"
ACTIVE = "active"
PARKED = "parked"
DONE = "done"


@dataclasses.dataclass
class Session:
    sid: int
    prompt: Any                        # (s,) int32 tokens (device or host)
    prompt_len: int
    budget: int                        # max new tokens (incl. the prefill one)
    phase: str = WAITING
    bank: int = -1                     # placement, valid while ACTIVE
    slot: int = -1                     # global slot id
    emitted: int = 0
    tokens: Any = None                 # final (s + emitted,) output when DONE
    gen: Any = None                    # per-request GenConfig (sampling params)
    parked: Any = None                 # host PageState while PARKED
    parks: int = 0                     # times preempted
    admit_step: int = -1               # pool.decode_steps at last (re-)admission
    first_admit_step: int = -1         # ... at FIRST admission (TTFT anchor)

    @property
    def finished(self) -> bool:
        return self.emitted >= self.budget


class SessionTable:
    """FIFO admission queue + slot-indexed lookup of active sessions."""

    def __init__(self):
        self._sessions: dict[int, Session] = {}
        self._queue: list[int] = []               # WAITING, arrival order
        self._by_slot: dict[int, int] = {}        # global slot -> sid
        self._next = 0

    def __len__(self):
        return len(self._sessions)

    def add(self, prompt, prompt_len: int, budget: int) -> Session:
        s = Session(self._next, prompt, prompt_len, budget)
        self._next += 1
        self._sessions[s.sid] = s
        self._queue.append(s.sid)
        return s

    def get(self, sid: int) -> Session:
        return self._sessions[sid]

    def next_waiting(self) -> Session | None:
        return self._sessions[self._queue[0]] if self._queue else None

    def peek_waiting(self, k: int) -> list[Session]:
        """First ``k`` queued sessions in FIFO order (WAITING and PARKED
        interleaved as they arrived / were parked) — the admission
        planner's window."""
        return [self._sessions[sid] for sid in self._queue[:k]]

    def activate(self, sid: int, bank: int, slot: int) -> Session:
        s = self._sessions[sid]
        assert s.phase in (WAITING, PARKED), \
            f"session {sid} is {s.phase}, not admissible"
        assert sid in self._queue, f"session {sid} is not queued"
        self._queue.remove(sid)
        s.phase, s.bank, s.slot = ACTIVE, bank, slot
        self._by_slot[slot] = sid
        return s

    def park(self, sid: int) -> Session:
        """ACTIVE -> PARKED: the session loses its slot and re-queues at
        the tail (so fresh arrivals admit first — the natural anti-thrash
        ordering).  The caller owns the page save/free."""
        s = self._sessions[sid]
        assert s.phase == ACTIVE, f"session {sid} is {s.phase}, not active"
        del self._by_slot[s.slot]
        s.phase, s.bank, s.slot = PARKED, -1, -1
        self._queue.append(sid)
        return s

    def at_slot(self, slot: int) -> Session | None:
        sid = self._by_slot.get(slot)
        return self._sessions[sid] if sid is not None else None

    def finish(self, sid: int, tokens) -> Session:
        s = self._sessions[sid]
        if s.phase == ACTIVE:
            del self._by_slot[s.slot]
        elif s.phase in (WAITING, PARKED):        # cancellation path
            self._queue.remove(sid)
        s.phase, s.tokens = DONE, tokens
        s.parked = None
        return s

    def active(self) -> list[Session]:
        return [self._sessions[sid] for sid in sorted(self._by_slot.values())]

    def waiting_count(self) -> int:
        return len(self._queue)

    def active_count(self) -> int:
        return len(self._by_slot)

    def all_done(self) -> bool:
        return not self._queue and not self._by_slot

    def outputs(self) -> dict[int, Any]:
        """Non-destructive view of every DONE session's tokens."""
        return {sid: s.tokens for sid, s in self._sessions.items()
                if s.phase == DONE}

    def collect_finished(self) -> dict[int, Any]:
        """Outputs of sessions finished since the last collection; the
        collected sessions are evicted from the table, so a long-running
        service's memory stays bounded and a later collection never
        re-delivers an old result."""
        return {sid: s.tokens
                for sid, s in self.collect_finished_sessions().items()}

    def collect_finished_sessions(self) -> dict[int, Session]:
        """Like :meth:`collect_finished` but hands back the whole popped
        Session — the gateway needs the admission/preemption history
        (``first_admit_step``, ``parks``) for its SLO accounting, not just
        the tokens."""
        done = [sid for sid, s in self._sessions.items() if s.phase == DONE]
        return {sid: self._sessions.pop(sid) for sid in done}

    def parked_count(self) -> int:
        return sum(1 for sid in self._queue
                   if self._sessions[sid].phase == PARKED)
