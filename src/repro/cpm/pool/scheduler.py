"""The MASIM-style multi-bank stream packer.

MASIM (arXiv:2412.02218) treats scheduling work across multiple in-memory
SIMD arrays as its own subsystem: requests target individual arrays, the
scheduler batches them so each array executes ONE broadcast stream.  Here
the arrays are :class:`~repro.cpm.pool.bank.CPMBank`\\ s and the requests are
per-session instruction streams (PR 4's ``CPMProgram`` ops with per-slot
operands): :meth:`MultiBankScheduler.submit` queues one session's stream
against its (bank, slot) placement, and :meth:`flush` packs every queued
stream of a bank into one *batched* ``CPMProgram`` over the bank's
``(slots, width)`` device — per-slot operands scattered into per-row operand
arrays, idle rows given identity operands — and executes it once per bank.
On the pallas backend a fusable template (e.g. the serving commit's
``insert -> truncate``) is therefore ONE ``fused_stream`` mega-kernel launch
per bank per flush, regardless of how many sessions committed.

Streams packed into one flush must share a *template* — the same op
sequence with the same static operands (SPMD across slots, exactly MASIM's
same-kernel batching constraint); mixed templates raise.  Idle-row identity
operands exist for ``insert`` (append at the row's own tail — writes land
beyond ``used_len``), ``truncate`` (keep the row's current length) and
``shift`` (empty range); templates whose trailing ``truncate`` restores
idle rows' lengths (as the commit template does) leave non-participating
pages bit-untouched within their live region.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs import metrics as _obs_metrics

from ..array import CPMArray
from ..program import CPMProgram, schedule
from .bank import CPMBank

# registry-backed launch accounting, one label (sched="<id>") per
# scheduler instance — host ints only, nothing device-side
_SCHED_IDS = itertools.count()
_SCHED_FAMILIES = {
    "flushes": _obs_metrics.counter(
        "repro_sched_flushes_total", "multi-bank flush calls", ("sched",)),
    "streams_packed": _obs_metrics.counter(
        "repro_sched_streams_packed_total",
        "per-session streams packed into batched launches", ("sched",)),
    "bank_launches": _obs_metrics.counter(
        "repro_sched_bank_launches_total",
        "batched program launches across banks", ("sched",)),
}

#: operand names treated as dynamic (per-slot) per op; everything else in an
#: instruction is static and must agree across the packed streams
_DYNAMIC: dict[str, dict[str, int]] = {
    "insert": {"pos": 0, "values": 1},
    "truncate": {"new_len": 0},
    "shift": {"start": 0, "end": 0},
    "compare": {"datum": 0},
    "delete": {"pos": 0},
}

#: ops with a per-row identity default for rows that did not submit
_HAS_IDENTITY = frozenset({"insert", "truncate", "shift"})


@dataclasses.dataclass(frozen=True)
class _Pending:
    slot: int
    ops: tuple[tuple[str, dict[str, Any]], ...]

    def template(self):
        """(op, sorted static operand items) per instruction — the SPMD
        signature two streams must share to pack into one launch.  Static
        operands must be hashable primitives (per-slot values belong in the
        op's dynamic operands, ``_DYNAMIC``)."""
        sig = []
        for op, operands in self.ops:
            dyn = _DYNAMIC.get(op, {})
            statics = []
            for k, v in operands.items():
                if k in dyn:
                    continue
                if not isinstance(v, (int, float, str, bool, type(None),
                                      tuple)):
                    raise TypeError(
                        f"{op}.{k}: static operands must be primitives, "
                        f"got {type(v).__name__} (per-slot values go in "
                        f"the dynamic operands: {sorted(dyn)})")
                statics.append((k, v))
            sig.append((op, tuple(sorted(statics))))
        return tuple(sig)


class MultiBankScheduler:
    """Packs per-session streams into one batched launch per bank."""

    # thin views over each scheduler's registry series (repro.obs) — the
    # attribute arithmetic (`sched.bank_launches += n`) is the accounting
    flushes = _obs_metrics.series_property("flushes")
    streams_packed = _obs_metrics.series_property("streams_packed")
    bank_launches = _obs_metrics.series_property("bank_launches")

    def __init__(self, banks: list[CPMBank]):
        self.banks = banks
        self._queues: list[list[_Pending]] = [[] for _ in banks]
        self._jitted: dict = {}
        label = str(next(_SCHED_IDS))
        self._obs_series = {
            k: fam.labels(sched=label) for k, fam in _SCHED_FAMILIES.items()}

    def submit(self, bank: int, slot: int, ops) -> None:
        """Queue one session's instruction stream for ``(bank, slot)``.

        ``ops``: sequence of ``(op_name, operand_dict)``; per-slot operand
        values may be traced/device scalars or ``(k,)`` vectors."""
        b = self.banks[bank]
        if not 0 <= slot < b.slots:
            raise IndexError(f"slot {slot} out of range for bank {bank} "
                             f"({b.slots} slots)")
        self._queues[bank].append(
            _Pending(slot, tuple((op, dict(d)) for op, d in ops)))

    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    def flush(self) -> dict:
        """Execute every queued stream: one batched program run per bank.

        Returns ``{"banks": touched, "streams": packed}``; bank state is
        updated in place."""
        touched = streams = 0
        for bank_id, queue in enumerate(self._queues):
            if not queue:
                continue
            self._run_bank(bank_id, queue)
            touched += 1
            streams += len(queue)
            queue.clear()
        self.flushes += 1
        self.streams_packed += streams
        self.bank_launches += touched
        return {"banks": touched, "streams": streams}

    # -- one bank: scatter operands, run once -------------------------------
    def _run_bank(self, bank_id: int, queue: list[_Pending]) -> None:
        bank = self.banks[bank_id]
        template = queue[0].template()
        for p in queue[1:]:
            if p.template() != template:
                raise ValueError(
                    f"bank {bank_id}: streams with different templates "
                    f"cannot pack into one launch ({p.template()} vs "
                    f"{template}); flush between template changes")
        slots_seen = set()
        for p in queue:
            if p.slot in slots_seen:
                raise ValueError(f"bank {bank_id}: two streams target slot "
                                 f"{p.slot} in one flush")
            slots_seen.add(p.slot)

        idx = jnp.asarray([p.slot for p in queue], jnp.int32)
        full = len(queue) == bank.slots
        dyn_ops: list[dict[str, jax.Array]] = []
        for i, (op, _) in enumerate(template):
            dyn_names = _DYNAMIC.get(op, {})
            batched: dict[str, jax.Array] = {}
            for name, rank in dyn_names.items():
                vals = [p.ops[i][1].get(name) for p in queue]
                if all(v is None for v in vals):
                    continue
                if any(v is None for v in vals):
                    raise ValueError(
                        f"bank {bank_id}: {op}.{name} is bound by only "
                        f"some of the packed streams; every stream in a "
                        f"flush must supply the same dynamic operands")
                shape = (-1,) if rank else ()
                stacked = jnp.stack([jnp.asarray(v).reshape(shape)
                                     for v in vals])      # (K,) or (K, k)
                if full:                 # every row participates: the
                    base = jnp.zeros(    # scatter below covers all rows,
                        (bank.slots,) + stacked.shape[1:],   # base values
                        stacked.dtype)                       # never read
                else:
                    base = self._identity_operand(bank, op, name, stacked)
                batched[name] = base.at[idx].set(stacked.astype(base.dtype))
            dyn_ops.append(batched)

        run = self._compiled(bank_id, template,
                             tuple(tuple(sorted(d)) for d in dyn_ops))
        data, lens = run(bank.data, bank.lens, dyn_ops)
        bank.update(CPMArray(data, lens, bank.backend, bank.interpret))

    def _identity_operand(self, bank: CPMBank, op: str, name: str,
                          stacked) -> jax.Array:
        """Per-row default that makes ``op`` a no-op within idle rows' live
        regions (see module docstring)."""
        if op not in _HAS_IDENTITY:
            raise ValueError(
                f"op {op!r} has no idle-row identity operand; submit a "
                f"stream for every slot of the bank or split the flush")
        r = bank.slots
        if op == "insert":
            if name == "pos":
                return bank.lens                    # append into dead space
            return jnp.zeros((r, stacked.shape[-1]), bank.dtype)  # values
        if op == "truncate":
            return bank.lens                        # keep current length
        # shift: empty [1, 0] range moves nothing
        if name == "start":
            return jnp.ones((r,), jnp.int32)
        return jnp.zeros((r,), jnp.int32)

    def compiled_commit(self, bank_id: int, k: int, rows: int | None = None):
        """The serving hot path's packing, pre-collapsed: every row of the
        bank runs the same ``insert(k tokens) -> truncate`` stream, so the
        per-session operand scatter reduces to stacked vectors and the
        whole flush to one pure function —

            ``(data, lens, toks (slots, k), emit (slots,)) -> (data, lens)``

        — appending each row's ``k`` chunk tokens at its tail and rolling
        the length register back to ``lens + emit`` (rows with ``emit 0``
        are bit-untouched in their live region; overshoot tokens beyond a
        row's budget land past ``used_len`` and are never visible).  Built
        on the same ``CPMProgram`` + fusing scheduler as :meth:`flush`
        (ONE fused mega-kernel launch per call on a pallas bank), but with
        no per-call Python packing, so a compiled serving step can inline
        it.  Not jitted here — callers embed it in their own programs.

        ``rows`` overrides the row count when the bank's physical rows are
        sub-pages (the paged pool): the commit then runs on the caller's
        gathered *logical* rows, not on the bank buffer directly."""
        bank = self.banks[bank_id]
        return packed_commit(bank.backend, bank.interpret,
                             bank.slots if rows is None else rows, k)

    def _compiled(self, bank_id: int, template, dyn_sig):
        """One jitted executor per (bank, template, operand-name signature):
        rebuilds the batched program from traced operands and runs the PR-4
        fusing scheduler against the bank device inside the jit."""
        bank = self.banks[bank_id]
        key = (bank_id, template, dyn_sig)
        if key not in self._jitted:
            ops = [op for op, _ in template]
            stat_items = [dict(s) for _, s in template]

            def run(data, lens, dyn):
                dev = CPMArray(data, lens, bank.backend, bank.interpret)
                prog = CPMProgram()
                for op, st, dy in zip(ops, stat_items, dyn):
                    prog.append(op, **st, **dy)
                out, _ = schedule(prog).run(dev, backend=bank.backend,
                                            interpret=bank.interpret)
                return out.data, jnp.broadcast_to(
                    jnp.asarray(out.used_len, jnp.int32), (bank.slots,))

            self._jitted[key] = jax.jit(run)
        return self._jitted[key]


@functools.lru_cache(maxsize=None)
def packed_commit(backend: str, interpret: bool | None, slots: int, k: int):
    """Pure packed-commit builder (see
    :meth:`MultiBankScheduler.compiled_commit`).  Parameterized by bank
    *shape and routing* only — the returned closure holds no bank or
    scheduler objects, so long-lived caches (an engine's compiled-program
    table) that embed it never pin a discarded pool's device buffers."""
    def run(data, lens, toks, emit):
        dev = CPMArray(data, lens, backend, interpret)
        prog = (CPMProgram()
                .append("insert", pos=lens, values=toks)
                .append("truncate", new_len=lens + emit))
        out, _ = schedule(prog).run(dev, backend=backend,
                                    interpret=interpret)
        return out.data, jnp.broadcast_to(
            jnp.asarray(out.used_len, jnp.int32), (slots,))

    return run
