"""repro.cpm.pool — paged multi-tenant CPM banks.

The pool layer turns single devices into a *facility*: fixed-shape banks of
pages (:class:`CPMBank`), a page-table allocator whose free-list and victim
searches are themselves CPM ops on a metadata device
(:class:`SlotAllocator` — the memory managing the memory, §4.2 +
arXiv:2203.00662), and a MASIM-style scheduler
(:class:`MultiBankScheduler`, arXiv:2412.02218) that packs per-session
instruction streams into ONE batched fused launch per bank.  Host-side
session lifecycle lives in :class:`SessionTable`.

The serving integration — continuous batching over pooled KV pages — is
``repro.serve.session_pool``, built on these four pieces.
"""

from .allocator import FREE, USED, OracleAllocator, SlotAllocator
from .bank import CPMBank
from .scheduler import MultiBankScheduler
from .sessions import ACTIVE, DONE, PARKED, WAITING, Session, SessionTable

__all__ = [
    "CPMBank",
    "SlotAllocator", "OracleAllocator", "FREE", "USED",
    "MultiBankScheduler",
    "SessionTable", "Session", "WAITING", "ACTIVE", "PARKED", "DONE",
]
