"""Pod-scale backend: chips as PEs via ``shard_map`` collectives.

The PE address axis is sharded over one named mesh axis; every op is the
paper's two-phase schedule — phase 1 inside each chip's registers, phase 2
across the ICI ring (`repro.cpm.collectives`).  When a sharding context from
``repro.distributed.sharding`` is active its mesh and innermost data axis
are used; otherwise a 1-axis mesh over all local devices is built.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .. import collectives
from . import _TableBacked


class MeshBackend(_TableBacked):
    name = "mesh"

    def __init__(self, mesh=None, axis: str | None = None,
                 mode: str = "two_phase"):
        if mesh is None:
            from repro.distributed import sharding
            ctx = sharding.current_ctx()
            if ctx.mesh is not None:
                mesh = ctx.mesh
                axis = axis or (ctx.data_axes[-1] if ctx.data_axes
                                else mesh.axis_names[0])
            else:
                devs = jax.devices()
                mesh = jax.make_mesh((len(devs),), ("cpm",))
                axis = "cpm"
        self.mesh = mesh
        self.axis = axis or mesh.axis_names[0]
        self.mode = mode

    @property
    def n_devices(self) -> int:
        return int(self.mesh.shape[self.axis])

    def _pad(self, x, fill):
        pad = (-x.shape[-1]) % self.n_devices
        if pad:
            x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                        constant_values=fill)
        return x

    def _spec(self, ndim: int):
        """Shard the last (PE address) axis; batch rows replicate."""
        return P(*([None] * (ndim - 1) + [self.axis]))

    def compare(self, x, datum, op="eq"):
        n = x.shape[-1]
        xp = self._pad(x, 0)
        from ..reference import comparable

        f = shard_map(partial(comparable.compare, datum=datum, op=op),
                      mesh=self.mesh, in_specs=self._spec(x.ndim),
                      out_specs=self._spec(x.ndim))
        return f(xp)[..., :n]

    def section_sum(self, x, section=None):
        xp = self._pad(x, 0)
        f = shard_map(
            lambda xl: collectives.distributed_section_sum(
                xl, self.axis, mode=self.mode),
            mesh=self.mesh, in_specs=self._spec(x.ndim), out_specs=P())
        return f(xp)

    def global_limit(self, x, mode="max", section=None):
        from ..semantics import limit_identity
        xp = self._pad(x, limit_identity(x.dtype, mode))
        f = shard_map(
            lambda xl: collectives.distributed_section_limit(
                xl, self.axis, mode=mode),
            mesh=self.mesh, in_specs=self._spec(x.ndim), out_specs=P())
        return f(xp)

    def super_sum(self, x, section=None):
        """§8 on chips: local partial per device, log-depth butterfly
        combine over the mesh axis (``collectives.tree_allreduce``).
        ``check_rep=False``: the ppermute butterfly leaves every device
        holding the full combine, but shard_map's static replication
        checker cannot prove that."""
        xp = self._pad(x, 0)
        f = shard_map(
            lambda xl: collectives.distributed_super_sum(xl, self.axis),
            mesh=self.mesh, in_specs=self._spec(x.ndim), out_specs=P(),
            check_rep=False)
        return f(xp)

    def super_limit(self, x, mode="max", section=None):
        from ..semantics import limit_identity
        xp = self._pad(x, limit_identity(x.dtype, mode))
        f = shard_map(
            lambda xl: collectives.distributed_super_limit(
                xl, self.axis, mode=mode),
            mesh=self.mesh, in_specs=self._spec(x.ndim), out_specs=P(),
            check_rep=False)
        return f(xp)
