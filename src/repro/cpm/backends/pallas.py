"""Chip-scale backend: VMEM Pallas kernels (VREG lanes = PEs).

Adapter over `repro.kernels.cpm_kernels`.  Row-wise kernels see a flattened
``(rows, n)`` layout (batch dims collapse to rows); reductions are
row-batched and HBM-tiled inside the kernels themselves — a batched
``(..., N)`` layout is ONE ``pallas_call`` over a (rows, sections) grid,
never a vmap over per-row launches, and N may exceed one VMEM block.
``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere —
the ``interpret=`` plumbing the kernels already expose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import cpm_kernels as K

from ..optable import optimal_section
from . import _TableBacked


def _rows(x):
    """(..., n) -> ((R, n), unflatten)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    if x.ndim == 1:
        x2 = x.reshape(1, -1)
    return x2, (lambda out: out.reshape(*lead, out.shape[-1]))


class PallasBackend(_TableBacked):
    name = "pallas"

    def __init__(self, interpret: bool | None = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)

    def activate(self, n, start, end, carry=1):
        return K.activate(n, start, end, carry, interpret=self.interpret)

    def shift_range(self, x, start, end, shift, fill=None):
        x2, un = _rows(x)
        return un(K.shift_range(x2, start, end, shift, fill,
                                interpret=self.interpret))

    def substring_match(self, hay, needle):
        x2, un = _rows(hay)
        return un(K.substring_match(x2, needle,
                                    interpret=self.interpret).astype(bool))

    def compare(self, x, datum, op="eq"):
        x2, un = _rows(x)
        return un(K.compare(x2, datum, op, interpret=self.interpret))

    def histogram(self, x, edges, section=None):
        sec = min(section or 1024, x.shape[-1])
        return K.histogram(x, edges, sec, interpret=self.interpret)

    def section_sum(self, x, section=None):
        sec = section or optimal_section(x.shape[-1])
        out = K.section_sum(x, sec, interpret=self.interpret)
        # match the reference accumulation dtype (jnp.sum semantics)
        ref_dtype = jnp.zeros((), x.dtype).sum().dtype
        return out.astype(ref_dtype)

    def global_limit(self, x, mode="max", section=None):
        sec = section or optimal_section(x.shape[-1])
        return K.section_limit(x, sec, mode, interpret=self.interpret)

    def super_sum(self, x, section=None):
        sec = section or optimal_section(x.shape[-1])
        out = K.super_sum(x, sec, interpret=self.interpret)
        return out.astype(jnp.zeros((), x.dtype).sum().dtype)

    def super_limit(self, x, mode="max", section=None):
        sec = section or optimal_section(x.shape[-1])
        return K.super_limit(x, sec, mode, interpret=self.interpret)

    def sort(self, x, steps=None):
        x2, un = _rows(x)
        return un(K.oddeven_sort(x2, steps, interpret=self.interpret))

    def template_match(self, data, template):
        x2, un = _rows(data)
        return un(K.template_match(x2, template, interpret=self.interpret))

    def stencil(self, x, taps, wrap=False):
        x2, un = _rows(x)
        return un(K.stencil(x2, tuple(float(t) for t in taps), wrap=wrap,
                            interpret=self.interpret))

    def compact(self, x, keep, fill=0):
        lead = x.shape[:-1]
        x2, un = _rows(x)
        k2 = jnp.broadcast_to(keep, x.shape).reshape(x2.shape)
        out, new_len = K.compact(x2, k2, fill, interpret=self.interpret)
        return un(out), (new_len.reshape(lead) if lead
                         else new_len.reshape(()))

    def fused_stream(self, x, used_len, instrs, operands):
        """One ``pallas_call`` for a whole fused instruction group: the row
        block and its §4.2 length register stay resident in VMEM across
        every instruction (see ``cpm_kernels.fused_stream``)."""
        return K.fused_stream(x, used_len, instrs, operands,
                              interpret=self.interpret)
