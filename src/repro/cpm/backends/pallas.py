"""Chip-scale backend: VMEM Pallas kernels (VREG lanes = PEs).

Adapter over `repro.kernels.cpm_kernels`.  Row-wise kernels see a flattened
``(rows, n)`` layout (batch dims collapse to rows); reductions are
row-batched and HBM-tiled inside the kernels themselves — a batched
``(..., N)`` layout is ONE ``pallas_call`` over a (rows, sections) grid,
never a vmap over per-row launches, and N may exceed one VMEM block.
``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere —
the ``interpret=`` plumbing the kernels already expose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import cpm_kernels as K

from .. import tuning
from ..optable import optimal_section
from . import _TableBacked


def _rows(x):
    """(..., n) -> ((R, n), unflatten)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    if x.ndim == 1:
        x2 = x.reshape(1, -1)
    return x2, (lambda out: out.reshape(*lead, out.shape[-1]))


class PallasBackend(_TableBacked):
    name = "pallas"

    def __init__(self, interpret: bool | None = None):
        self.interpret = K.resolve_interpret(interpret)

    def _tuned_section(self, op: str, x, default: int, run) -> int:
        """Autotuned section (VMEM block width) for one reduction call,
        cached per (op, shape, dtype, backend) with a JSON spill.  The
        candidate grid spans the ~sqrt(N) paper choice through whole-row
        blocks; ``run(section)`` times candidates on synthesized zeros —
        outside any active trace only (``tuning.measurable``); traced
        callers get cache hits or the static default.  An explicit
        ``section=`` from the caller always bypasses tuning (this is
        only reached when it was None)."""
        n = x.shape[-1]
        default = min(default, n)
        if n < 2048:                    # tuning overhead beats any return
            return default
        cands = sorted({min(c, n) for c in
                        (optimal_section(n), 256, 1024, 4096, n)})
        key = (f"section:{op}|{'x'.join(map(str, x.shape))}"
               f"|{jnp.dtype(x.dtype).name}"
               f"|{tuning.backend_key(self.interpret)}")
        return int(tuning.pick(key, cands, run, default=default))

    def activate(self, n, start, end, carry=1):
        return K.activate(n, start, end, carry, interpret=self.interpret)

    def shift_range(self, x, start, end, shift, fill=None):
        x2, un = _rows(x)
        return un(K.shift_range(x2, start, end, shift, fill,
                                interpret=self.interpret))

    def substring_match(self, hay, needle):
        x2, un = _rows(hay)
        return un(K.substring_match(x2, needle,
                                    interpret=self.interpret).astype(bool))

    def compare(self, x, datum, op="eq"):
        x2, un = _rows(x)
        return un(K.compare(x2, datum, op, interpret=self.interpret))

    def histogram(self, x, edges, section=None):
        if section is None:
            xz = tuning.synth(x.shape, x.dtype)
            ez = tuning.synth(edges.shape, edges.dtype)
            section = self._tuned_section(
                f"histogram{edges.shape[-1] - 1}", x, 1024,
                lambda s: K.histogram(xz, ez, s, interpret=self.interpret))
        sec = min(section, x.shape[-1])
        return K.histogram(x, edges, sec, interpret=self.interpret)

    def section_sum(self, x, section=None):
        if section is None:
            xz = tuning.synth(x.shape, x.dtype)
            section = self._tuned_section(
                "section_sum", x, optimal_section(x.shape[-1]),
                lambda s: K.section_sum(xz, s, interpret=self.interpret))
        out = K.section_sum(x, section, interpret=self.interpret)
        # match the reference accumulation dtype (jnp.sum semantics)
        ref_dtype = jnp.zeros((), x.dtype).sum().dtype
        return out.astype(ref_dtype)

    def global_limit(self, x, mode="max", section=None):
        if section is None:
            xz = tuning.synth(x.shape, x.dtype)
            section = self._tuned_section(
                "section_limit", x, optimal_section(x.shape[-1]),
                lambda s: K.section_limit(xz, s, mode,
                                          interpret=self.interpret))
        return K.section_limit(x, section, mode, interpret=self.interpret)

    def super_sum(self, x, section=None):
        if section is None:
            xz = tuning.synth(x.shape, x.dtype)
            section = self._tuned_section(
                "super_sum", x, optimal_section(x.shape[-1]),
                lambda s: K.super_sum(xz, s, interpret=self.interpret))
        out = K.super_sum(x, section, interpret=self.interpret)
        return out.astype(jnp.zeros((), x.dtype).sum().dtype)

    def super_limit(self, x, mode="max", section=None):
        if section is None:
            xz = tuning.synth(x.shape, x.dtype)
            section = self._tuned_section(
                "super_limit", x, optimal_section(x.shape[-1]),
                lambda s: K.super_limit(xz, s, mode,
                                        interpret=self.interpret))
        return K.super_limit(x, section, mode, interpret=self.interpret)

    def sort(self, x, steps=None):
        x2, un = _rows(x)
        return un(K.oddeven_sort(x2, steps, interpret=self.interpret))

    def template_match(self, data, template):
        x2, un = _rows(data)
        return un(K.template_match(x2, template, interpret=self.interpret))

    def stencil(self, x, taps, wrap=False):
        x2, un = _rows(x)
        return un(K.stencil(x2, tuple(float(t) for t in taps), wrap=wrap,
                            interpret=self.interpret))

    def compact(self, x, keep, fill=0):
        lead = x.shape[:-1]
        x2, un = _rows(x)
        k2 = jnp.broadcast_to(keep, x.shape).reshape(x2.shape)
        out, new_len = K.compact(x2, k2, fill, interpret=self.interpret)
        return un(out), (new_len.reshape(lead) if lead
                         else new_len.reshape(()))

    def fused_stream(self, x, used_len, instrs, operands, block_r: int = 1):
        """One ``pallas_call`` for a whole fused instruction group: the row
        block and its §4.2 length register stay resident in VMEM across
        every instruction (see ``cpm_kernels.fused_stream``).  ``block_r``
        rows per grid step — the executor autotunes it per stream
        signature."""
        return K.fused_stream(x, used_len, instrs, operands,
                              block_r=block_r, interpret=self.interpret)
