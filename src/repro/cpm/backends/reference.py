"""The always-available pure-`jnp` backend (the oracle).

Thin adapter over `repro.cpm.reference.*` — the paper's ops lowered to
constant counts of full-array vector primitives.  Shapes: every op works on
the last axis; reductions are row-batched (``(..., N)`` -> ``(...,)``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import reference as R
from . import _TableBacked


class ReferenceBackend(_TableBacked):
    name = "reference"

    def activate(self, n, start, end, carry=1):
        return R.pe_array.activation_mask(n, start, end, carry)

    def shift_range(self, x, start, end, shift, fill=None):
        return R.movable.shift_range(x, start, end, shift, fill)

    def substring_match(self, hay, needle):
        return R.searchable.substring_match(hay, needle)

    def compare(self, x, datum, op="eq"):
        return R.comparable.compare(x, datum, op)

    def histogram(self, x, edges):
        return R.comparable.histogram(x, edges)

    def section_sum(self, x, section=None):
        return R.computable.section_sum(x, section)

    def global_limit(self, x, mode="max", section=None):
        return R.computable.section_limit(x, section, mode)

    def super_sum(self, x, section=None):
        return R.computable.super_sum(x, section)

    def super_limit(self, x, mode="max", section=None):
        return R.computable.super_limit(x, section, mode)

    def sort(self, x, steps=None):
        # full sort: jnp.sort is the XLA-native realization of the N-step
        # odd-even exchange (bitwise-equal output — sorting is a function
        # of the value multiset).  A bounded local phase keeps the paper's
        # step structure.
        if steps is not None:
            return R.computable.odd_even_sort(x, steps)
        return jnp.sort(x, axis=-1)

    def template_match(self, data, template):
        return R.computable.template_match_1d(data, template)

    def stencil(self, x, taps, wrap=False):
        return R.computable.stencil_1d(x, taps, wrap=wrap)

    def compact(self, x, keep, fill=0):
        return R.movable.compact(x, keep, fill)
