"""Physical realizations of the CPM instruction set.

One :class:`Backend` protocol, three embodiments of the same memory device:

  * ``reference`` — pure ``jnp`` vector ops (`repro.cpm.reference`).  Always
    available; the oracle the other two are validated against.
  * ``pallas``    — VMEM kernels (`repro.kernels.cpm_kernels`): the VMEM
    block is the PE array, VREG lanes are PEs.  ``interpret=`` is plumbed
    through so CPU containers execute the kernel bodies.
  * ``mesh``      — chips as PEs: ``shard_map`` collectives over a named
    mesh axis (`repro.cpm.collectives`), wired to the partition rules in
    ``repro.distributed.sharding`` when a sharding context is active.

``resolve`` picks a backend automatically from array residency/size, or
honors an explicit request (raising if the op is not realizable there — the
paper's pin-compatibility promise is per-op, checked against the op table).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax

from ..optable import OP_TABLE

#: rows shorter than this are not worth a kernel launch — stay on reference
PALLAS_MIN_N = 1024


@runtime_checkable
class Backend(Protocol):
    """The broadcast-instruction surface every physical realization offers.

    All ops treat the **last axis** as the PE address axis.  Reductions
    (`section_sum`, `global_limit`, `histogram`, `super_sum`, `super_limit`)
    are row-batched: ``(..., N)`` in, ``(...,)`` (or ``(..., M)`` bins) out —
    batched `CPMArray` layouts dispatch as ONE backend call, never a
    vmap-over-launch.
    """

    name: str

    def supports(self, op: str) -> bool: ...
    def activate(self, n: int, start, end, carry=1): ...
    def shift_range(self, x, start, end, shift: int, fill=None): ...
    def substring_match(self, hay, needle): ...          # match-END flags
    def compare(self, x, datum, op: str = "eq"): ...
    def histogram(self, x, edges): ...
    def section_sum(self, x, section=None): ...
    def global_limit(self, x, mode: str = "max", section=None): ...
    def super_sum(self, x, section=None): ...            # §8 log-depth
    def super_limit(self, x, mode: str = "max", section=None): ...
    def sort(self, x, steps=None): ...
    def template_match(self, data, template): ...
    def stencil(self, x, taps, wrap: bool = False): ...
    def compact(self, x, keep, fill=0): ...              # (out, new_len)

    def fused_stream(self, x, used_len, instrs, operands,
                     block_r: int = 1):
        """Execute a fused instruction group (``repro.cpm.program``) in one
        launch (``block_r`` rows per grid step — autotuned by the
        executor).  Optional capability: only backends that can keep the
        row resident across instructions implement it (pallas); the
        scheduler falls back to per-op replay elsewhere."""
        raise NotImplementedError(
            f"backend {self.name!r} has no fused-stream realization")


class _TableBacked:
    """supports() read off the op table (single source of truth)."""

    name: str = "?"

    def supports(self, op: str) -> bool:
        spec = OP_TABLE.get(op)
        return spec is not None and self.name in spec.backends


def _registry():
    from . import mesh, pallas, reference
    return {
        "reference": reference.ReferenceBackend,
        "pallas": pallas.PallasBackend,
        "mesh": mesh.MeshBackend,
    }


_INSTANCES: dict = {}


def get_backend(name: str, **kw) -> Backend:
    """Instantiate a backend by name (``reference`` | ``pallas`` | ``mesh``).

    Instances are memoized per (name, kwargs) — resolve() runs per op call,
    and MeshBackend's constructor builds a device mesh, which must not be
    repeated in eager loops.  Unhashable kwargs (e.g. an explicit Mesh)
    fall back to a fresh instance.
    """
    reg = _registry()
    if name not in reg:
        raise ValueError(f"unknown CPM backend {name!r}; have {sorted(reg)}")
    extra = ()
    if name == "mesh":
        # default mesh construction reads the (mutable) sharding context —
        # a cached instance is only valid while that context is unchanged
        from repro.distributed import sharding
        extra = (sharding.current_ctx(),)
    try:
        key = (name, tuple(sorted(kw.items())), extra)
        if key not in _INSTANCES:
            _INSTANCES[key] = reg[name](**kw)
        return _INSTANCES[key]
    except TypeError:                      # unhashable kwarg / ctx
        return reg[name](**kw)


def _residency(data) -> str:
    """Platform holding ``data`` — falls back to the default backend for
    tracers (inside jit the concrete residency is the jit target's)."""
    try:
        return next(iter(data.devices())).platform
    except Exception:
        return jax.default_backend()


def pallas_min_n(op: str | None = None) -> int:
    """Minimum last-axis length for auto routing to pallas.

    Consults the shared tuning cache for a *measured* reference/pallas
    crossover — ``xover:<op>:<backend_key>`` entries written by the
    ``cpm_ops`` benchmark's crossover sweep (per-op first, then the
    ``*`` pooled entry) — and falls back to the static
    :data:`PALLAS_MIN_N` when nothing was measured on this backend.
    Small-N arrays thereby route to reference instead of paying pallas
    launch overhead, with the threshold grounded in timings rather than
    folklore."""
    from .. import tuning
    bk = tuning.backend_key(False)
    for key in ([f"xover:{op}:{bk}"] if op else []) + [f"xover:*:{bk}"]:
        n = tuning.lookup(key)
        if n is not None:
            return int(n)
    return PALLAS_MIN_N


def auto_backend_name(data, op: str | None = None) -> str:
    """The ``backend="auto"`` policy, defined once: Pallas when the array
    lives on a TPU and the row is long enough to amortize a kernel launch
    (threshold per :func:`pallas_min_n` — measured crossover when the
    tuning cache has one), reference otherwise.  Shared by per-op
    ``resolve`` and the program executor
    (``repro.cpm.program.executors``) so eager dispatch and plan
    execution can never pick different backends for the same array."""
    if _residency(data) == "tpu" and data.shape[-1] >= pallas_min_n(op):
        return "pallas"
    return "reference"


def resolve(requested: str, op: str, data, *, interpret=None) -> Backend:
    """Pick the backend for one op call.

    ``requested == "auto"``: Pallas when the array lives on a TPU and the row
    is long enough to amortize a kernel launch; otherwise the reference
    lowering (which XLA fuses into the surrounding program).  Ops outside a
    backend's table entry fall back to reference under auto but raise when
    the backend was forced.
    """
    if requested == "auto":
        if (auto_backend_name(data, op) == "pallas"
                and "pallas" in OP_TABLE[op].backends):
            # honor an explicit interpret hint (debugging); default compiled
            return get_backend("pallas",
                               interpret=False if interpret is None
                               else interpret)
        return get_backend("reference")
    if requested not in _registry():
        raise ValueError(f"unknown CPM backend {requested!r}; "
                         f"have {sorted(_registry())}")
    # table check BEFORE instantiation: MeshBackend builds a device mesh
    # in __init__, which should not run (or mask this error) for an op
    # the backend cannot realize anyway
    if requested not in OP_TABLE[op].backends:
        raise NotImplementedError(
            f"op {op!r} is not realizable on the {requested!r} backend "
            f"(table says {OP_TABLE[op].backends}); use backend='auto' "
            f"to fall back to reference")
    return get_backend(requested, **({"interpret": interpret}
                                     if requested == "pallas" else {}))
