"""`CPMArray` — one memory device, any physical realization.

The paper's promise is a memory that is "general-purposed, easy to use, pin
compatible with conventional memory": you issue broadcast instructions to a
device and never care whether the PEs are VREG lanes, VMEM rows, or chips on
a mesh.  `CPMArray` is that surface: a pytree-registered value holding

  * ``data``     — the physical buffer ``(*batch, n)``; the last axis is the
                   PE address axis,
  * ``used_len`` — the tracked logical length (§4.2 "memory managing
                   itself"), a **traced** scalar (or per-batch vector) so one
                   compiled program serves every length,
  * ``backend``/``interpret`` — static routing hints (aux data).

Every paper operation dispatches through the backend registry
(``repro.cpm.backends``) and is registered once in the op table
(``repro.cpm.optable``) with its concurrent-step-count formula —
``steps_report()`` and the benchmarks validate the paper's complexity table
from that single source of truth.

Ops that read the used region mask the tail identically on every backend,
so differential tests demand bit-identical results for every discrete op
(activate, moves, matches, compares, sort) and for integer reductions;
float reductions (`section_sum`) may differ by accumulation order across
backends and agree to float tolerance instead.  Reductions
(`section_sum`, `global_limit`, `histogram`, `super_sum`, `super_limit`)
are row-batched: a ``(*batch, n)`` layout with per-row ``used_len``
dispatches as ONE backend call — one Pallas launch over a
(rows, sections) grid, never a vmap over per-row launches.  ``jax.vmap``
still works (the pytree registration carries ``data`` and ``used_len``
together); the in-place move ops expect a scalar ``used_len`` per call —
vmap over the array for per-row lengths.

Every op method is also a *recordable* instruction: inside
``with cpm.record() as prog:`` the call is appended to a
:class:`~repro.cpm.program.CPMProgram` (and still returns its real value),
so a method-call pipeline becomes an instruction stream the fusing
scheduler can lower to single-launch Pallas mega-kernels — see
``repro.cpm.program``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import backends, semantics
from .optable import OP_TABLE, op_steps
from .program.ir import recordable
from .reference import movable, pe_array


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class CPMArray:
    data: jax.Array                    # (*batch, n) physical buffer
    used_len: jax.Array                # () or (*batch,) logical length
    backend: str = "auto"              # "auto" | "reference" | "pallas" | "mesh"
    interpret: bool | None = None      # pallas only; None = auto (off-TPU)

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.used_len), (self.backend, self.interpret)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, used_len = children
        return cls(data, used_len, *aux)

    # -- layout -------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.data.shape[-1]

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return self.data.shape[:-1]

    @property
    def dtype(self):
        return self.data.dtype

    def _with(self, **kw) -> "CPMArray":
        return dataclasses.replace(self, **kw)

    def _b(self, op: str):
        return backends.resolve(self.backend, op, self.data,
                                interpret=self.interpret)

    def _live(self) -> jax.Array:
        """Used-region mask, broadcast against the batch layout."""
        ul = jnp.asarray(self.used_len)
        addr = jnp.arange(self.n)
        return addr < (ul[..., None] if ul.ndim else ul)

    # -- family: activate (Rule 4) -----------------------------------------
    @recordable("activate")
    def activate(self, start, end, carry=1) -> jax.Array:
        """General-decoder activation mask over the PE address axis."""
        return self._b("activate").activate(self.n, start, end, carry)

    # -- family: move (§4) ---------------------------------------------------
    @recordable("shift")
    def shift(self, start, end, shift: int = 1, fill=None) -> "CPMArray":
        """Concurrent range move; ``used_len`` is unchanged."""
        data = self._b("shift").shift_range(self.data, start, end, shift, fill)
        return self._with(data=data)

    @recordable("insert")
    def insert(self, pos, values) -> "CPMArray":
        """Insert ``values`` at ``pos``: range shift + broadcast write
        (~2 concurrent steps).  ``used_len`` grows (clipped to ``n``)."""
        values = jnp.asarray(values, self.dtype)
        k = values.shape[-1]
        shifted = self._b("insert").shift_range(
            self.data, pos, self.used_len - 1, k, None)
        data = movable.write_window(shifted, pos, values)
        return self._with(data=data,
                          used_len=jnp.minimum(self.used_len + k, self.n))

    @recordable("delete")
    def delete(self, pos, k: int, fill=0) -> "CPMArray":
        """Delete ``k`` items at ``pos``: the tail shifts left, vacated slots
        take ``fill``, ``used_len`` shrinks."""
        shifted = self._b("delete").shift_range(
            self.data, pos + k, self.used_len - 1, -k, None)
        data = movable.fill_deleted_tail(shifted, self.used_len, k,
                                         jnp.asarray(fill, self.dtype))
        return self._with(data=data,
                          used_len=jnp.maximum(self.used_len - k, 0))

    @recordable("truncate")
    def truncate(self, new_len) -> "CPMArray":
        """Range delete at the tail: O(1), lengths only (entries stay put;
        the used-region mask excludes them)."""
        new_len = jnp.asarray(new_len, jnp.int32)
        return self._with(used_len=jnp.minimum(self.used_len, new_len))

    # -- family: search (§5) -------------------------------------------------
    @recordable("substring_match")
    def substring_match(self, needle, where: str = "start") -> jax.Array:
        """Match an M-item needle everywhere in the used region (~M steps).

        Canonical convention: flags at match **start** addresses
        (``where="end"`` gives the paper's raw carry-chain view; the two are
        one `repro.cpm.semantics` converter apart).
        """
        needle = jnp.asarray(needle, self.dtype)
        ends = self._b("substring_match").substring_match(self.data, needle)
        ends = ends & self._live()
        if where == "end":
            return ends
        if where != "start":
            raise ValueError(f"where must be 'start' or 'end', got {where!r}")
        return semantics.ends_to_starts(ends, needle.shape[-1])

    @recordable("find_all")
    def find_all(self, needle, max_out: int):
        """Start addresses of every occurrence (ascending) via Rule 6."""
        starts = self.substring_match(needle, where="start")
        return pe_array.enumerate_matches(starts, max_out)

    # -- family: compare (§6) ------------------------------------------------
    @recordable("compare")
    def compare(self, datum, op: str = "eq", mask=None) -> jax.Array:
        """One concurrent compare against a broadcast datum, tail masked."""
        if mask is not None:                   # bit-field compare: int domain
            x, d = self.data & mask, jnp.asarray(datum, self.dtype) & mask
        else:                                  # value compare: promote, don't
            d = jnp.asarray(datum)             # truncate (e.g. int x vs 2.5)
            ct = jnp.promote_types(self.dtype, d.dtype)
            x, d = self.data.astype(ct), d.astype(ct)
        got = self._b("compare").compare(x, d, op)
        return got & self._live()

    @recordable("count")
    def count(self, datum, op: str = "eq", mask=None) -> jax.Array:
        """Rule-6 parallel count of matching PEs."""
        return pe_array.count_matches(self.compare(datum, op, mask))

    @recordable("histogram")
    def histogram(self, edges) -> jax.Array:
        """Per-row M-bin histogram of the used region (~M compare+count
        steps).  Batched ``(*batch, n)`` layouts dispatch as ONE backend
        call (one Pallas launch over a rows x sections grid) and return
        ``(*batch, M)`` counts."""
        edges = jnp.asarray(edges)
        ct = jnp.promote_types(self.dtype, edges.dtype)
        x, e = self.data.astype(ct), edges.astype(ct)
        # tail values take the top edge, which lands in no [e_i, e_{i+1}) bin
        x = jnp.where(self._live(), x, e[-1])
        return self._b("histogram").histogram(x, e)

    # -- family: compute / reduce (§7–§8) ------------------------------------
    def _masked(self, fill) -> jax.Array:
        return jnp.where(self._live(), self.data,
                         jnp.asarray(fill, self.dtype))

    @recordable("section_sum")
    def section_sum(self, section: int | None = None) -> jax.Array:
        """Two-phase per-row sum of the used region (~2·sqrt(N) steps).

        Batched layouts reduce in ONE backend call — ``(*batch, n)`` data
        with ``(*batch,)`` (or scalar) ``used_len`` returns ``(*batch,)``
        sums from a single tiled kernel launch on the pallas backend.
        """
        return self._b("section_sum").section_sum(self._masked(0), section)

    @recordable("global_limit")
    def global_limit(self, mode: str = "max",
                     section: int | None = None) -> jax.Array:
        """Two-phase per-row max/min of the used region (§7.5); batched
        layouts reduce in ONE backend call like :meth:`section_sum`."""
        fill = semantics.limit_identity(self.dtype, mode)
        return self._b("global_limit").global_limit(self._masked(fill),
                                                    mode, section)

    @recordable("super_sum")
    def super_sum(self, section: int | None = None) -> jax.Array:
        """§8 super-connected per-row sum: log-depth trees in both phases,
        ~2·log2(n)+1 concurrent steps instead of ~2·sqrt(n)+1.  Same value
        as :meth:`section_sum` (bit-identical for integer dtypes)."""
        return self._b("super_sum").super_sum(self._masked(0), section)

    @recordable("super_limit")
    def super_limit(self, mode: str = "max",
                    section: int | None = None) -> jax.Array:
        """§8 super-connected per-row max/min (log-depth phase 1 + 2)."""
        fill = semantics.limit_identity(self.dtype, mode)
        return self._b("super_limit").super_limit(self._masked(fill),
                                                  mode, section)

    @recordable("sort")
    def sort(self, steps: int | None = None, fill=0) -> "CPMArray":
        """Ascending sort of the used prefix; tail slots take ``fill``.

        ``steps`` bounds the odd-even exchange cycles (``None`` = full sort).
        """
        if jnp.issubdtype(self.dtype, jnp.integer):
            big = jnp.iinfo(self.dtype).max
        else:
            big = jnp.inf
        x = jnp.where(self._live(), self.data, jnp.asarray(big, self.dtype))
        out = self._b("sort").sort(x, steps)
        data = jnp.where(self._live(), out, jnp.asarray(fill, self.dtype))
        return self._with(data=data)

    @recordable("template_match")
    def template_match(self, template, mask_tail: bool = True) -> jax.Array:
        """SAD of an M-item template at every start address (~M steps).

        Start positions whose window runs past the used region are invalid;
        ``mask_tail=True`` (canonical) pins them to ``+inf`` so every backend
        reports the identical, well-defined result.  ``mask_tail=False``
        exposes the raw wrapping output.
        """
        template = jnp.asarray(template)
        out = self._b("template_match").template_match(self.data, template)
        if mask_tail:
            out = semantics.mask_window_tail(out, template.shape[-1],
                                             self.used_len)
        return out

    @recordable("stencil")
    def stencil(self, taps, wrap: bool = False) -> jax.Array:
        """§7.3 tap-algebra stencil (~M steps).

        Canonical (``wrap=False``): the used region with zero padding — tail
        slots contribute nothing.  ``wrap=True`` is exactly the historical
        ring over the full physical buffer (tail content included), so
        migrated callers get the old numbers bit-for-bit.
        """
        if wrap:
            return self._b("stencil").stencil(self.data, taps, wrap=True)
        x = jnp.where(self._live(), self.data, jnp.asarray(0, self.dtype))
        return self._b("stencil").stencil(x, taps, wrap=False)

    @recordable("compact")
    def compact(self, keep, fill=0) -> "CPMArray":
        """Stable §4.2 pack: flagged items move to the front, order kept.

        ``keep`` flags select survivors inside the used region (dead-slot
        flags are ignored); vacated tail slots take ``fill`` and
        ``used_len`` becomes the survivor count.  The paper moves each
        object by a range shift; the TPU-native realization is a stable
        log-depth cumsum-gather — one argsort pack on the reference
        backend, one Pallas launch (Hillis-Steele cumsum + lower-bound
        gather in VMEM) on pallas, bit-identical per row.
        """
        keep = jnp.asarray(keep, bool) & self._live()
        data, new_len = self._b("compact").compact(
            self.data, keep, jnp.asarray(fill, self.dtype))
        return self._with(data=data, used_len=new_len)

    # -- introspection -------------------------------------------------------
    def steps_report(self, *, needle_len: int = 8, bins: int = 8,
                     template_len: int = 8, taps_len: int = 3,
                     section: int | None = None) -> dict[str, int]:
        """Concurrent-step count of every registered op at this array's size,
        evaluated from the op table and checked against the paper bounds."""
        n = self.n
        m_of = {"substring_match": needle_len, "histogram": bins,
                "template_match": template_len, "stencil": taps_len}
        return {name: op_steps(name, n=n, m=m_of.get(name, 0),
                               section=section)
                for name in OP_TABLE}


def cpm_array(data, used_len=None, backend: str = "auto",
              interpret: bool | None = None) -> CPMArray:
    """Canonical constructor: coerces ``data`` to a jax array and defaults
    ``used_len`` to the full physical length."""
    data = jnp.asarray(data)
    if used_len is None:
        used_len = data.shape[-1]
    used_len = jnp.asarray(used_len, jnp.int32)
    return CPMArray(data, used_len, backend, interpret)
