"""The autotune / calibration cache shared by the cost model and kernels.

Both the cost-aware scheduler (``repro.cpm.program.costmodel``) and the
self-tuning pallas layer (``repro.cpm.backends.pallas`` section choice,
``repro.cpm.program.executors`` fused-stream row blocking) need the same
two things:

  * a **memoization surface** keyed by a string the caller derives from
    ``(op-stream-signature, shape, dtype, backend)`` — an in-process dict
    backed by a JSON spill so decisions survive across processes (CI
    uploads the spill next to the BENCH files);
  * a **timing harness** that measures candidate realizations on
    synthesized inputs.  Measurement only happens **outside any active
    trace** (:func:`measurable`): under ``jit``/``make_jaxpr``,
    omnistaging would stage every "timed" dispatch into the caller's
    jaxpr — measuring tracing instead of execution and polluting the
    traced program — so traced callers get cache hits (decisions made
    earlier, eagerly) or their static defaults.

Environment knobs:

  * ``REPRO_CPM_TUNING_CACHE`` — spill path (default
    ``~/.cache/repro/cpm_tuning.json``).  Set it into the workspace in CI
    so the artifact rides along with ``BENCH_*.json``.
  * ``REPRO_CPM_AUTOTUNE=0`` — disable measurement: every lookup misses
    and callers fall back to their static defaults (useful for
    deterministic debugging).
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

#: in-process cache: key -> JSON-serializable decision value
_MEM: dict[str, Any] = {}
_LOADED = False


def cache_path() -> str:
    return os.environ.get(
        "REPRO_CPM_TUNING_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "cpm_tuning.json"))


def tuning_enabled() -> bool:
    return os.environ.get("REPRO_CPM_AUTOTUNE", "1") != "0"


def _load_spill() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    try:
        with open(cache_path()) as f:
            spill = json.load(f)
        if isinstance(spill, dict):
            for k, v in spill.items():
                _MEM.setdefault(k, v)
    except (OSError, ValueError):
        pass


def lookup(key: str):
    """Cached decision for ``key`` or None (miss)."""
    _load_spill()
    return _MEM.get(key)


def store(key: str, value) -> None:
    """Record a decision and spill the whole cache to JSON (best effort:
    an unwritable cache path degrades to in-process memoization only)."""
    _load_spill()
    _MEM[key] = value
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(_MEM, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


def clear(in_process_only: bool = True) -> None:
    """Drop cached decisions (tests)."""
    global _LOADED
    _MEM.clear()
    _LOADED = in_process_only   # True keeps the spill from reloading


def entries(prefix: str = "") -> dict:
    """Snapshot of cached decisions whose key starts with ``prefix``
    (benchmarks report the tuner's choices; CI ships them as an artifact)."""
    _load_spill()
    return {k: v for k, v in _MEM.items() if k.startswith(prefix)}


def backend_key(interpret: bool) -> str:
    """The backend axis of every cache key: pallas kernels behave like a
    different machine under the interpreter than compiled on TPU."""
    return (f"pallas-{'interpret' if interpret else 'compiled'}"
            f"-{jax.default_backend()}")


def measurable() -> bool:
    """True when no trace is active, i.e. candidate timing would measure
    real execution.  Inside ``jit``/``vmap``/``make_jaxpr`` tracing, a
    "timed" jit dispatch is *staged* into the enclosing jaxpr instead of
    run (omnistaging), so the wall clock would measure tracing and the
    staged calls would pollute the traced program — callers must skip
    measurement and fall back to cached decisions or static defaults."""
    return jax.core.trace_state_clean()


def synth(shape, dtype):
    """Concrete zeros for candidate timing.  Forced concrete (instead of
    a bare ``jnp.zeros``) so a caller probing the cache from inside a
    trace does not leave staged zero-constants behind in the enclosing
    jaxpr.  Note ``jax.ensure_compile_time_eval`` must stay *out* of any
    pallas dispatch path: an ambient eval trace makes kernel-internal
    index math concrete, which ``pallas_call`` rejects as captured
    constants — hence zeros-only here, and :func:`measurable` gating
    every actual timing."""
    with jax.ensure_compile_time_eval():
        return jnp.zeros(shape, dtype)


def time_call(fn: Callable[[], Any], reps: int = 5) -> float:
    """Best-of-``reps`` wall-clock seconds of ``fn()`` after one warmup
    (the warmup also pays compilation).  Only meaningful when
    :func:`measurable` — callers gate on it."""
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def pick(key: str, candidates: list, run: Callable[[Any], Any],
         default, reps: int = 3):
    """Cached argmin-time choice among ``candidates``.

    ``run(c)`` executes one candidate on synthesized inputs; failures (a
    candidate invalid for the shape) disqualify that candidate.  With
    tuning disabled, an active trace (see :func:`measurable`), or every
    candidate failing, returns ``default`` without caching, so the
    decision can be made later under better conditions.
    """
    cached = lookup(key)
    if cached is not None:
        return cached
    if not tuning_enabled() or not measurable() or not candidates:
        return default
    best, best_t = default, float("inf")
    for c in candidates:
        try:
            t = time_call(lambda: run(c), reps=reps)
        except Exception:
            continue
        if t < best_t:
            best, best_t = c, t
    if best_t == float("inf"):
        return default
    store(key, best)
    return best
