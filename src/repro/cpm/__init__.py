"""repro.cpm — the paper's memory device behind one operator surface.

Public API:

  * :class:`CPMArray` / :func:`cpm_array` — the pytree-registered memory
    device (physical buffer + tracked ``used_len``); every paper op is a
    method dispatching to a physical backend.
  * ``backends`` — the :class:`~repro.cpm.backends.Backend` protocol and the
    ``reference`` / ``pallas`` / ``mesh`` realizations.
  * ``OP_TABLE`` / :func:`op_steps` — the op registry with each op's
    concurrent-step-count formula (the complexity table of §3–§8, registered
    once — including the §8 super-connected ``super_sum``/``super_limit``).
  * ``semantics`` — the canonical result conventions (match-start flags,
    masked window tails) and the converters between them.
  * ``reference`` — the pure-`jnp` op modules (formerly ``repro.core``).
  * ``collectives`` — the shard_map embodiment used by the mesh backend.
  * ``program`` — instruction streams as first-class values:
    :func:`record` traces ``CPMArray`` method calls into a
    :class:`CPMProgram`, :func:`schedule` partitions the stream into fusion
    groups, and each fused group runs as ONE Pallas mega-kernel on the
    pallas backend (reference replays unfused, mesh maps over shards).
  * ``pool`` — paged multi-tenant banks: fixed-shape page arrays
    (``CPMBank``), the self-managing page-table allocator whose free-list/
    victim search is itself CPM compare/limit ops (``SlotAllocator``), and
    the MASIM-style ``MultiBankScheduler`` packing per-session streams
    into one batched fused launch per bank.
"""

from . import backends, collectives, optable, pool, program, reference, semantics
from .array import CPMArray, cpm_array
from .backends import Backend, get_backend
from .optable import FAMILIES, OP_TABLE, fusable_ops, op_steps, ops_for_backend
from .program import CPMProgram, FusionPlan, record, schedule
from .semantics import ends_to_starts, mask_window_tail, starts_to_ends, window_valid

__all__ = [
    "CPMArray", "cpm_array",
    "Backend", "get_backend", "backends",
    "OP_TABLE", "op_steps", "ops_for_backend", "fusable_ops", "FAMILIES",
    "optable",
    "CPMProgram", "FusionPlan", "record", "schedule", "program",
    "ends_to_starts", "starts_to_ends", "window_valid", "mask_window_tail",
    "semantics", "reference", "collectives",
]
