"""Distributed embodiment of CPM at pod scale.

Chips are PEs: Rule 7 (neighbor connectivity) is the ICI torus, realized with
``jax.lax.ppermute`` rings; Rule 5 (broadcast instruction) is the SPMD
program; the paper's §7.4 two-phase sectioned reduction becomes hierarchical
mesh collectives (reduce inside a section of the mesh, then across sections);
the §8 *super-connectivity* extension (log N skip links) is the
butterfly/tree all-reduce XLA natively emits.

Three gradient-reduction schedules, selectable in the trainer:
  * ``ring``       — R7-faithful: N-1 ppermute steps, neighbor-only links.
  * ``two_phase``  — §7.4: psum over the inner ("data") axis then the outer
                     ("pod") axis; the paper's sectioned sum on the mesh.
  * ``xla``        — single psum over all axes (the §8 super-connectivity /
                     log-depth schedule, left to the XLA collective compiler).

All functions must run inside ``shard_map`` (they use axis names).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis_name: str) -> int:
    """Static mesh-axis size.  ``lax.axis_size`` only exists in newer JAX;
    ``psum`` of a Python constant constant-folds to the axis size on every
    version this repo supports."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def ring_shift(x: jax.Array, axis_name: str, shift: int = 1) -> jax.Array:
    """Rule 7: read a register of the neighbor ``shift`` hops away (ring)."""
    n = _axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, axis_name, perm)


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Neighbor-only all-reduce: N-1 ppermute+add steps (R7-faithful).

    Bandwidth-inefficient vs reduce-scatter+all-gather but structurally the
    paper's phase-1 section reduction (a carry marching around the ring).
    """
    n = _axis_size(axis_name)
    acc = x

    def body(i, carry):
        acc, moving = carry
        moving = ring_shift(moving, axis_name, 1)
        return acc + moving, moving

    acc, _ = lax.fori_loop(0, n - 1, body, (acc, x))
    return acc


def ring_reduce_scatter(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    """Bandwidth-optimal ring reduce-scatter: N-1 steps, each moving 1/N of x.

    Chunk layout: chunk ``(rank + 1 + i)
    % N`` is forwarded at step i; after N-1 steps each rank holds the full sum
    of its own chunk. This is the schedule real pods run; here it documents
    the lowering we expect XLA to produce for psum_scatter.
    """
    return lax.psum_scatter(x, axis_name, scatter_dimension=axis, tiled=True)


def ring_allgather(x: jax.Array, axis_name: str, axis: int = 0) -> jax.Array:
    return lax.all_gather(x, axis_name, axis=axis, tiled=True)


def hierarchical_psum(x: jax.Array, inner_axis: str, outer_axis: str | None,
                      mode: str = "two_phase") -> jax.Array:
    """§7.4 two-phase sum generalized to the mesh.

    Phase 1: concurrent reduction inside each section (= inner mesh axis,
    e.g. the 16-chip "data" ring of one pod).  Phase 2: reduction across
    sections (= outer "pod" axis).  ``mode`` picks the phase-1 schedule.
    """
    if mode == "ring":
        out = ring_allreduce(x, inner_axis)
    elif mode == "two_phase":
        out = lax.psum(x, inner_axis)
    elif mode == "xla":
        axes = (inner_axis,) if outer_axis is None else (inner_axis, outer_axis)
        return lax.psum(x, axes)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    if outer_axis is not None:
        out = lax.psum(out, outer_axis)
    return out


def tree_allreduce(x: jax.Array, axis_name: str, combine=None) -> jax.Array:
    """§8 super-connectivity: log2(N) butterfly exchange via ppermute.

    Level j exchanges with the PE 2**j away — exactly Fig. 16's skip links.
    Requires a power-of-two axis size.  ``combine`` defaults to addition;
    any associative-commutative op (max/min) gives the same log-depth
    schedule for the §7.5 limits.
    """
    n = _axis_size(axis_name)
    assert n & (n - 1) == 0, "tree_allreduce needs power-of-two axis"
    combine = jnp.add if combine is None else combine
    acc = x
    j = 1
    while j < n:
        perm = [(i, i ^ j) for i in range(n)]
        acc = combine(acc, lax.ppermute(acc, axis_name, perm))
        j <<= 1
    return acc


def grad_sync(grads, mesh_axes: tuple[str, ...], mode: str = "two_phase"):
    """Synchronize a gradient pytree across data-parallel mesh axes.

    mesh_axes is ("data",) or ("pod", "data"); the inner-most axis is the
    section (phase 1), the outer the cross-section (phase 2).
    """
    inner = mesh_axes[-1]
    outer = mesh_axes[0] if len(mesh_axes) > 1 else None
    f = partial(hierarchical_psum, inner_axis=inner, outer_axis=outer, mode=mode)
    return jax.tree.map(f, grads)


# ---------------------------------------------------------------------------
# distributed §7.4: the sectioned sum with chips as sections
# ---------------------------------------------------------------------------

def distributed_section_sum(x_local: jax.Array, axis_name: str,
                            mode: str = "two_phase") -> jax.Array:
    """Per-row global sum of a last-axis-sharded array: local section sum
    (phase 1 inside each PE's registers), then cross-PE combine (phase 2
    over the ring).  ``(..., N/devices)`` local shards -> replicated
    ``(...,)`` — batch rows reduce concurrently in the one collective."""
    local = jnp.sum(x_local, axis=-1)
    if mode == "ring":
        return ring_allreduce(local, axis_name)
    return lax.psum(local, axis_name)


def distributed_section_limit(x_local: jax.Array, axis_name: str,
                              mode: str = "max") -> jax.Array:
    local = jnp.max(x_local, axis=-1) if mode == "max" else jnp.min(x_local, axis=-1)
    return lax.pmax(local, axis_name) if mode == "max" else lax.pmin(local, axis_name)


def distributed_super_sum(x_local: jax.Array, axis_name: str) -> jax.Array:
    """§8 on the mesh: local partial, then the log-depth butterfly combine
    (Fig. 16 skip links = ICI all-to-all reach).  Non-power-of-two axes fall
    back to ``psum`` — XLA's own log-depth schedule."""
    local = jnp.sum(x_local, axis=-1)
    n = _axis_size(axis_name)
    if n & (n - 1) == 0:
        return tree_allreduce(local, axis_name)
    return lax.psum(local, axis_name)


def distributed_super_limit(x_local: jax.Array, axis_name: str,
                            mode: str = "max") -> jax.Array:
    local = jnp.max(x_local, axis=-1) if mode == "max" else jnp.min(x_local, axis=-1)
    n = _axis_size(axis_name)
    combine = jnp.maximum if mode == "max" else jnp.minimum
    if n & (n - 1) == 0:
        return tree_allreduce(local, axis_name, combine=combine)
    return lax.pmax(local, axis_name) if mode == "max" else lax.pmin(local, axis_name)
