"""Jaxpr-walk introspection behind the fusion and step-count invariants.

The subsystem's contracts are stated in lowered-jaxpr terms — "one
``pallas_call`` per fused group", "scan trip counts equal the registered
concurrent-step formulas" — so the walker that measures them lives here,
once, and the tests, benchmarks and examples all import it.  The walk
descends into sub-jaxprs held directly in eqn params (scan/while bodies)
and into sequences of them (e.g. ``lax.cond`` branch tuples).
"""

from __future__ import annotations

import jax


def _walk(jaxpr, visit) -> None:
    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                _walk(v.jaxpr, visit)
            elif isinstance(v, (tuple, list)):
                for b in v:
                    if hasattr(b, "jaxpr"):
                        _walk(b.jaxpr, visit)


def count_pallas_calls(fn, *args) -> int:
    """Number of ``pallas_call`` eqns in ``fn``'s jaxpr — the launch count
    the fused-group invariant is asserted against."""
    n = 0

    def visit(eqn):
        nonlocal n
        if eqn.primitive.name == "pallas_call":
            n += 1

    _walk(jax.make_jaxpr(fn)(*args).jaxpr, visit)
    return n


def scan_trip_count(fn, *args) -> int:
    """Total ``lax.scan`` trip count of ``fn``'s lowering — the *measured*
    concurrent-step structure (each trip is one broadcast instruction
    cycle), compared against the op-table formulas."""
    total = 0

    def visit(eqn):
        nonlocal total
        if eqn.primitive.name == "scan":
            total += int(eqn.params["length"])

    _walk(jax.make_jaxpr(fn)(*args).jaxpr, visit)
    return total
