"""The fusing scheduler and the whole-program cycle-cost model.

``schedule`` partitions an instruction stream into :class:`FusionGroup`\\ s
with one rule, read off the op table's single source of truth
(``OpSpec.fusable``): maximal runs of elementwise/local ops that share the
device buffer fuse into one group — on the pallas backend each fused group
is ONE ``fused_stream`` mega-kernel launch that keeps the section resident
in VMEM across instructions.  Everything else (two-phase/§8 reductions,
histogram, sort, Rule-6 drains) is a ``boundary`` group of one instruction,
executed by ordinary per-op dispatch.

Fusing is *cost-aware* when the caller supplies the device (or explicit
shape info): each fusable run is priced both ways by the launch/byte model
in :mod:`~repro.cpm.program.costmodel` — backend-calibrated launch
intercepts and per-byte slopes over the op table's cost metadata — and a
run predicted slower fused is emitted as an ``eager`` group (per-op
dispatch, same instructions, bit-identical results).  The verdict rides in
``FusionGroup.decision`` and surfaces through ``describe()`` /
``steps_report()``.  Without device info ``schedule`` keeps the PR-4
behavior: every fusable run fuses (the launch-bound default).

The cycle model sums the ``OP_TABLE`` concurrent-step formulas per
instruction (operand sizes — needle/template/tap lengths, bin counts — are
read from the recorded operands).  ``scan_structured_steps`` restricts the
sum to ops whose *reference lowering* is a literal ``lax.scan``; the
benchmarks and tests assert it equals the jaxpr-measured trip count of the
unfused replay, exactly as PR 3 did per op.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from ..optable import fusable_ops, op_steps
from .ir import DERIVED_METHODS as _DERIVED
from .ir import CPMProgram, Instruction

#: methods whose reference lowering is a literal scan over concurrent steps
_SCAN_STRUCTURED = ("substring_match", "find_all", "template_match",
                    "super_sum", "super_limit")


def _operand_len(v) -> int:
    """Trailing-axis length of a recorded operand WITHOUT materializing it:
    tracers, ShapeDtypeStructs and plain lists all answer from metadata
    (``jnp.asarray`` here would force a device transfer at schedule time)."""
    shape = getattr(v, "shape", None)
    if shape is None:
        shape = np.shape(v)
    if len(shape) == 0:
        raise ValueError(f"expected a vector operand, got scalar {v!r}")
    return int(shape[-1])


def _instr_m(instr: Instruction) -> int:
    """The op-specific size M, read from the recorded operand shapes."""
    ops = instr.operands
    if instr.op in ("substring_match", "find_all"):
        return _operand_len(ops["needle"])
    if instr.op == "histogram":
        return _operand_len(ops["edges"]) - 1
    if instr.op == "template_match":
        return _operand_len(ops["template"])
    if instr.op == "stencil":
        return len(ops["taps"])
    return 0


def instruction_steps(instr: Instruction, n: int,
                      section: int | None = None) -> int:
    """Concurrent-step count of one instruction at device size ``n``
    (bound-checked against the paper's ceiling by ``op_steps``)."""
    if instr.op == "sort" and instr.operands.get("steps") is not None:
        return int(instr.operands["steps"])   # bounded local exchange phase
    table_op = _DERIVED.get(instr.op, instr.op)
    extra = 1 if instr.op in _DERIVED else 0  # the Rule-6 count/drain step
    sec = instr.operands.get("section")       # explicit None check: a
    if sec is None:                           # recorded section=0 must
        sec = section                         # error, not silently fall
    if sec is not None and sec < 1:           # back to the caller default
        raise ValueError(
            f"{instr.op}: section must be >= 1, got {sec!r}")
    return op_steps(table_op, n=n, m=_instr_m(instr), section=sec) + extra


def program_steps(prog: CPMProgram, n: int,
                  section: int | None = None) -> int:
    """Total predicted concurrent cycles of the whole stream."""
    return sum(instruction_steps(i, n, section=section) for i in prog)


def scan_structured_steps(prog: CPMProgram, n: int) -> int:
    """Predicted cycles of the scan-lowered instructions only — the part a
    jaxpr walk of the *reference* replay measures as scan trip counts."""
    return sum(instruction_steps(i, n) - (1 if i.op in _DERIVED else 0)
               for i in prog if i.op in _SCAN_STRUCTURED)


# ---------------------------------------------------------------------------
# fusion groups
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusionGroup:
    kind: str                         # "fused" | "eager" | "boundary"
    indices: tuple[int, ...]          # instruction positions in the program
    instructions: tuple[Instruction, ...]
    #: the cost model's verdict for this run (None when scheduling was not
    #: cost-aware): {"fuse", "fused_us", "eager_us", "params"}
    decision: dict | None = field(default=None, compare=False)

    def __repr__(self):
        body = "; ".join(i.op for i in self.instructions)
        return f"<{self.kind} [{body}]>"


@dataclass(frozen=True)
class FusionPlan:
    program: CPMProgram
    groups: tuple[FusionGroup, ...]

    @property
    def fused_group_count(self) -> int:
        return sum(g.kind == "fused" for g in self.groups)

    def predicted_steps(self, n: int, section: int | None = None) -> int:
        return program_steps(self.program, n, section=section)

    def describe(self) -> str:
        lines = [f"CPMProgram: {len(self.program)} instructions -> "
                 f"{len(self.groups)} groups "
                 f"({self.fused_group_count} fused)"]
        for g in self.groups:
            tag = {"fused": "1 mega-kernel launch",
                   "eager": "per-op dispatch (cost model)"}.get(
                       g.kind, "per-op dispatch")
            cost = ""
            if g.decision is not None:
                cost = (f"  fused {g.decision['fused_us']:.2f}us vs "
                        f"eager {g.decision['eager_us']:.2f}us "
                        f"[{g.decision['params']}]")
            lines.append(f"  {g.kind:8s} {list(g.indices)} "
                         f"[{' -> '.join(i.op for i in g.instructions)}]  "
                         f"({tag}){cost}")
        return "\n".join(lines)

    def steps_report(self, n: int, section: int | None = None) -> dict:
        """The cycle model plus the schedule's fuse/eager verdicts."""
        report = self.program.steps_report(n, section=section)
        report["schedule"] = [
            {"kind": g.kind,
             "ops": [i.op for i in g.instructions],
             "decision": g.decision}
            for g in self.groups]
        return report

    def run(self, array, backend: str | None = None,
            interpret: bool | None = None):
        from . import executors
        return executors.run_plan(self, array, backend=backend,
                                  interpret=interpret)


def _device_geometry(device) -> tuple[int, int, int]:
    """(rows, n, itemsize) of anything CPMArray-shaped."""
    lead = device.batch_shape
    rows = math.prod(lead) if lead else 1
    return rows, device.n, device.data.dtype.itemsize


def schedule(prog: CPMProgram, device=None, *, backend: str | None = None,
             interpret: bool | None = None, cost=None) -> FusionPlan:
    """Greedy linear partition: maximal fusable runs, reductions as walls.

    With ``device`` (a ``CPMArray``) the partition is cost-aware: each
    fusable run fuses only when the launch/byte model predicts the single
    mega-kernel launch beats eager per-op dispatch on that backend —
    otherwise the run becomes an ``eager`` group (identical per-op
    execution, decision recorded).  ``backend`` / ``interpret`` default to
    the device's own; ``cost`` accepts an explicit
    :class:`~repro.cpm.program.costmodel.CostParams` (tests, what-if
    scheduling) instead of the calibrated/roofline coefficients.

    Without ``device``, every fusable run fuses — the PR-4 launch-bound
    default, and the only safe answer with no geometry to price.
    """
    params = None
    geometry = None
    lead, dtype, itp = (), None, None
    if device is not None or cost is not None:
        from . import costmodel            # circular at module load time
        bk = backend or (device.backend if device is not None else "pallas")
        if bk == "auto" and device is not None:
            from .. import backends as B
            bk = B.auto_backend_name(device.data)   # same rule as run_plan
        if bk == "pallas":
            if device is not None:
                geometry = _device_geometry(device)
                lead, dtype = device.batch_shape, device.data.dtype
                from repro.kernels.cpm_kernels import resolve_interpret
                itp = resolve_interpret(interpret if interpret is not None
                                        else device.interpret)
            if geometry is not None:
                params = cost if cost is not None \
                    else costmodel.params_for(itp)

    fus = fusable_ops()
    groups: list[FusionGroup] = []
    run: list[int] = []

    def flush():
        if not run:
            return
        instrs = tuple(prog.instructions[i] for i in run)
        kind, decision = "fused", None
        if params is not None:
            rows, n, itemsize = geometry
            decision = costmodel.decide(instrs, rows, n, itemsize, params,
                                        lead=lead, dtype=dtype,
                                        interpret=itp)
            kind = "fused" if decision["fuse"] else "eager"
        groups.append(FusionGroup(kind, tuple(run), instrs, decision))
        run.clear()

    for i, ins in enumerate(prog.instructions):
        if ins.op in fus:
            run.append(i)
        else:
            flush()
            groups.append(FusionGroup("boundary", (i,), (ins,)))
    flush()
    return FusionPlan(prog, tuple(groups))
