"""The fusing scheduler and the whole-program cycle-cost model.

``schedule`` partitions an instruction stream into :class:`FusionGroup`\\ s
with one rule, read off the op table's single source of truth
(``OpSpec.fusable``): maximal runs of elementwise/local ops that share the
device buffer fuse into one group — on the pallas backend each fused group
is ONE ``fused_stream`` mega-kernel launch that keeps the section resident
in VMEM across instructions.  Everything else (two-phase/§8 reductions,
histogram, sort, Rule-6 drains) is a ``boundary`` group of one instruction,
executed by ordinary per-op dispatch.

The cost model sums the ``OP_TABLE`` concurrent-step formulas per
instruction (operand sizes — needle/template/tap lengths, bin counts — are
read from the recorded operands).  ``scan_structured_steps`` restricts the
sum to ops whose *reference lowering* is a literal ``lax.scan``; the
benchmarks and tests assert it equals the jaxpr-measured trip count of the
unfused replay, exactly as PR 3 did per op.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from ..optable import fusable_ops, op_steps
from .ir import DERIVED_METHODS as _DERIVED
from .ir import CPMProgram, Instruction

#: methods whose reference lowering is a literal scan over concurrent steps
_SCAN_STRUCTURED = ("substring_match", "find_all", "template_match",
                    "super_sum", "super_limit")


def _instr_m(instr: Instruction) -> int:
    """The op-specific size M, read from the recorded operand shapes."""
    ops = instr.operands
    if instr.op in ("substring_match", "find_all"):
        return int(jnp.shape(jnp.asarray(ops["needle"]))[-1])
    if instr.op == "histogram":
        return int(jnp.shape(jnp.asarray(ops["edges"]))[-1]) - 1
    if instr.op == "template_match":
        return int(jnp.shape(jnp.asarray(ops["template"]))[-1])
    if instr.op == "stencil":
        return len(ops["taps"])
    return 0


def instruction_steps(instr: Instruction, n: int,
                      section: int | None = None) -> int:
    """Concurrent-step count of one instruction at device size ``n``
    (bound-checked against the paper's ceiling by ``op_steps``)."""
    if instr.op == "sort" and instr.operands.get("steps") is not None:
        return int(instr.operands["steps"])   # bounded local exchange phase
    table_op = _DERIVED.get(instr.op, instr.op)
    extra = 1 if instr.op in _DERIVED else 0  # the Rule-6 count/drain step
    sec = instr.operands.get("section") or section
    return op_steps(table_op, n=n, m=_instr_m(instr), section=sec) + extra


def program_steps(prog: CPMProgram, n: int,
                  section: int | None = None) -> int:
    """Total predicted concurrent cycles of the whole stream."""
    return sum(instruction_steps(i, n, section=section) for i in prog)


def scan_structured_steps(prog: CPMProgram, n: int) -> int:
    """Predicted cycles of the scan-lowered instructions only — the part a
    jaxpr walk of the *reference* replay measures as scan trip counts."""
    return sum(instruction_steps(i, n) - (1 if i.op in _DERIVED else 0)
               for i in prog if i.op in _SCAN_STRUCTURED)


# ---------------------------------------------------------------------------
# fusion groups
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FusionGroup:
    kind: str                         # "fused" | "boundary"
    indices: tuple[int, ...]          # instruction positions in the program
    instructions: tuple[Instruction, ...]

    def __repr__(self):
        body = "; ".join(i.op for i in self.instructions)
        return f"<{self.kind} [{body}]>"


@dataclass(frozen=True)
class FusionPlan:
    program: CPMProgram
    groups: tuple[FusionGroup, ...]

    @property
    def fused_group_count(self) -> int:
        return sum(g.kind == "fused" for g in self.groups)

    def predicted_steps(self, n: int, section: int | None = None) -> int:
        return program_steps(self.program, n, section=section)

    def describe(self) -> str:
        lines = [f"CPMProgram: {len(self.program)} instructions -> "
                 f"{len(self.groups)} groups "
                 f"({self.fused_group_count} fused)"]
        for g in self.groups:
            tag = ("1 mega-kernel launch" if g.kind == "fused"
                   else "per-op dispatch")
            lines.append(f"  {g.kind:8s} {list(g.indices)} "
                         f"[{' -> '.join(i.op for i in g.instructions)}]  "
                         f"({tag})")
        return "\n".join(lines)

    def run(self, array, backend: str | None = None,
            interpret: bool | None = None):
        from . import executors
        return executors.run_plan(self, array, backend=backend,
                                  interpret=interpret)


def schedule(prog: CPMProgram) -> FusionPlan:
    """Greedy linear partition: maximal fusable runs, reductions as walls."""
    fus = fusable_ops()
    groups: list[FusionGroup] = []
    run: list[int] = []

    def flush():
        if run:
            groups.append(FusionGroup(
                "fused", tuple(run),
                tuple(prog.instructions[i] for i in run)))
            run.clear()

    for i, ins in enumerate(prog.instructions):
        if ins.op in fus:
            run.append(i)
        else:
            flush()
            groups.append(FusionGroup("boundary", (i,), (ins,)))
    flush()
    return FusionPlan(prog, tuple(groups))
