"""The launch/byte wall-clock cost model behind cost-aware fusion.

The §3–§8 cycle model (``scheduler.program_steps``) prices programs in
*concurrent steps* — the paper's currency.  It says nothing about what a
kernel **launch** costs on a physical backend, which is exactly what
decides whether fusing a run of elementwise ops into one
``fused_stream`` mega-kernel is a win:

  * compiled on TPU, a launch has real cost and the fused group's single
    launch amortizes it over the whole run (the PR-4 premise);
  * under the Pallas interpreter on CPU/GPU hosts, "launches" are free —
    eager per-op dispatch jit-fuses into one XLA program while the
    mega-kernel adds interpreter overhead and blocks XLA fusion, which is
    how the committed ``BENCH_program_fusion.json`` ended up at 0.75x
    eager.

So the model prices a fusable run both ways in seconds::

    eager(group) = launches · L_e  + passes · bytes · c_e
    fused(group) = L_f            + passes · bytes · c_f

with per-op ``passes``/``launches`` read off the op table's cost metadata
(``OpSpec.passes`` / ``OpSpec.eager_launches``) and the four coefficients
either

  * **calibrated** — a one-time microbenchmark per backend key: a small
    fixed probe stream timed fused vs eager at two sizes, solved for the
    launch intercepts and per-byte slopes, spilled to the tuning-cache
    JSON (``repro.cpm.tuning``) for reuse across runs; or
  * **roofline priors** — ``analysis.roofline.HW`` HBM bandwidth plus a
    nominal launch cost, used where measurement is impossible or disabled
    (``REPRO_CPM_CALIBRATE=0``).  The priors make fusion profitable for
    any multi-op run — the correct TPU-side default.

``schedule(prog, device=...)`` consults :func:`decide` per fusable run
and records the verdict in the emitted :class:`FusionGroup`.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HW

from .. import tuning

#: nominal TPU-side kernel launch overhead (seconds) for the roofline
#: prior — order of a grid dispatch; only its *ratio* to the byte terms
#: matters for the fuse/eager sign
NOMINAL_LAUNCH_S = 2e-6

#: probe stream sizes (elements) for the two-point calibration fit
_PROBE_SIZES = (512, 8192)
_PROBE_REPS = 5


def calibration_enabled() -> bool:
    return os.environ.get("REPRO_CPM_CALIBRATE", "1") != "0"


@dataclass(frozen=True)
class CostParams:
    """Per-backend launch/byte coefficients (seconds / seconds-per-byte)."""
    launch_s: float            # eager per-op launch intercept  (L_e)
    eager_byte_s: float        # eager per-pass byte slope      (c_e)
    fused_launch_s: float      # fused single-launch intercept  (L_f)
    fused_byte_s: float        # fused per-pass byte slope      (c_f)
    source: str = "roofline"   # "calibrated" | "roofline" | "override"

    def as_dict(self) -> dict:
        return {"launch_s": self.launch_s,
                "eager_byte_s": self.eager_byte_s,
                "fused_launch_s": self.fused_launch_s,
                "fused_byte_s": self.fused_byte_s,
                "source": self.source}


def roofline_params() -> CostParams:
    """Priors from the §9 roofline HW table: byte slopes at HBM bandwidth
    (identical for both paths — launches decide), nominal launch cost."""
    byte_s = 1.0 / HW["hbm_bw"]
    return CostParams(NOMINAL_LAUNCH_S, byte_s, NOMINAL_LAUNCH_S, byte_s,
                      source="roofline")


# ---------------------------------------------------------------------------
# one-time microbenchmark calibration
# ---------------------------------------------------------------------------

def _probe_program(n: int):
    from .ir import CPMProgram
    return (CPMProgram()
            .append("shift", start=0, end=n // 2, shift=1, fill=0)
            .append("compare", datum=3, op="lt")
            .append("activate", start=0, end=n - 1, carry=1)
            .append("stencil", taps=(1.0, 2.0, 1.0), wrap=False))


def _time_probe(n: int, interpret: bool) -> tuple[float, float]:
    """(fused_s, eager_s) of the 4-op probe stream at size ``n``."""
    from ..array import CPMArray
    from . import executors
    from .scheduler import FusionGroup, FusionPlan, schedule

    prog = _probe_program(n)
    fused_plan = schedule(prog)                      # fuse-all baseline
    eager_plan = FusionPlan(prog, tuple(
        FusionGroup("eager", (i,), (ins,))
        for i, ins in enumerate(prog.instructions)))
    data = tuning.synth((n,), jnp.int32)

    def runner(plan):
        def go(d):
            arr = CPMArray(d, n, backend="pallas", interpret=interpret)
            cur, outs = executors.run_plan(plan, arr, backend="pallas",
                                           interpret=interpret)
            return cur.data, [o for o in outs if o is not None]
        return jax.jit(go)

    f_fused, f_eager = runner(fused_plan), runner(eager_plan)
    t_fused = tuning.time_call(lambda: f_fused(data), reps=_PROBE_REPS)
    t_eager = tuning.time_call(lambda: f_eager(data), reps=_PROBE_REPS)
    return t_fused, t_eager


def calibrate(interpret: bool) -> CostParams:
    """Fit the four coefficients from the probe at two sizes (int32, one
    row, k=4 ops): intercept = launch term, slope = per-byte term."""
    k = len(_probe_program(8).instructions)
    n1, n2 = _PROBE_SIZES
    b1, b2 = n1 * 4, n2 * 4
    tf1, te1 = _time_probe(n1, interpret)
    tf2, te2 = _time_probe(n2, interpret)
    c_e = max((te2 - te1) / (k * (b2 - b1)), 1e-15)
    c_f = max((tf2 - tf1) / (k * (b2 - b1)), 1e-15)
    l_e = max(te1 / k - c_e * b1, 1e-9)
    l_f = max(tf1 - k * c_f * b1, 1e-9)
    return CostParams(l_e, c_e, l_f, c_f, source="calibrated")


def params_for(interpret: bool) -> CostParams:
    """The coefficients for one backend key: tuning-cache hit, else a
    fresh calibration (spilled), else the roofline priors."""
    key = f"calib:{tuning.backend_key(interpret)}"
    cached = tuning.lookup(key)
    if isinstance(cached, dict):
        try:
            return CostParams(**cached)
        except TypeError:
            pass
    if not calibration_enabled() or not tuning.measurable():
        # under an active trace the probe would be staged, not timed —
        # price with the roofline priors (uncached, so a later eager
        # schedule still gets to calibrate)
        return roofline_params()
    try:
        params = calibrate(interpret)
    except Exception:
        return roofline_params()
    tuning.store(key, params.as_dict())
    return params


# ---------------------------------------------------------------------------
# the per-group decision
# ---------------------------------------------------------------------------

def _cost_meta(instr, n: int) -> tuple[int, int]:
    """(row passes, eager launches) of one instruction — op-table cost
    metadata, with the concurrent-step formula as the passes fallback."""
    from ..optable import OP_TABLE
    from .ir import DERIVED_METHODS
    from .scheduler import _instr_m

    spec = OP_TABLE[DERIVED_METHODS.get(instr.op, instr.op)]
    m = _instr_m(instr)
    if spec.passes is not None:
        return int(spec.passes(n=n, m=m)), spec.eager_launches
    return int(spec.steps(n=n, m=m)), spec.eager_launches


def group_cost(instructions, rows: int, n: int, itemsize: int,
               params: CostParams) -> tuple[float, float]:
    """Predicted (fused_s, eager_s) of one fusable run on ``rows`` rows of
    ``n`` elements."""
    nbytes = rows * n * itemsize
    passes = launches = 0
    for instr in instructions:
        p, l = _cost_meta(instr, n)
        passes += p
        launches += l
    eager_s = launches * params.launch_s + passes * nbytes * params.eager_byte_s
    fused_s = params.fused_launch_s + passes * nbytes * params.fused_byte_s
    return fused_s, eager_s


#: fuse only on a predicted *clear* win.  Eager per-op dispatch is the
#: safe baseline (same instructions, bit-identical results), while the
#: coefficients behind a near-tie prediction carry microbenchmark noise —
#: hysteresis keeps borderline runs on the structure that cannot regress.
#: Launch-bound regimes (the TPU case fusion exists for) predict ratios
#: far below this margin, so it never costs a real win.
FUSE_MARGIN = 0.85

#: when a *calibrated* prediction lands in this fused/eager ratio band,
#: the fit's noise exceeds the predicted gap — settle the verdict by
#: timing the actual group both ways on synthesized inputs instead
#: (cached per (op-stream, shape, dtype, backend) in the tuning spill).
#: Roofline priors and explicit overrides are never second-guessed.
MEASURE_BAND = (0.5, 1.5)
_MEASURE_REPS = 3


def _synth(v):
    """A timing stand-in for one recorded operand: arrays (including
    tracers — decisions can happen at trace time) become concrete zeros
    of the same shape/dtype; static Python values pass through."""
    if isinstance(v, (jax.Array, np.ndarray)):
        return tuning.synth(jnp.shape(v), v.dtype)
    return v


def _measured_fuse(instructions, lead, n: int, dtype,
                   interpret: bool) -> dict | None:
    """Time the run fused vs eager on a synthesized device of the real
    geometry; returns the verdict dict or None (cache miss while tuning
    is off or a trace is active, or measurement failure)."""
    from ..array import CPMArray
    from . import executors
    from .ir import CPMProgram
    from .scheduler import FusionGroup, FusionPlan, schedule

    sig = "+".join(i.op for i in instructions)
    key = (f"fuse:{sig}|{'x'.join(str(d) for d in lead) or 1}x{n}"
           f"|{jnp.dtype(dtype).name}|{tuning.backend_key(interpret)}")
    cached = tuning.lookup(key)
    if isinstance(cached, dict):
        return dict(cached, params="measured")
    if not tuning.tuning_enabled() or not tuning.measurable():
        return None

    prog = CPMProgram()
    for ins in instructions:
        prog = prog.append(ins.op,
                           **{k: _synth(v) for k, v in ins.operands.items()})
    fused_plan = schedule(prog)                  # bare: fuse-all, no device
    eager_plan = FusionPlan(prog, tuple(
        FusionGroup("eager", (i,), (ins,))
        for i, ins in enumerate(prog.instructions)))
    data = tuning.synth((*lead, n), dtype)
    used = jnp.full(lead, n, jnp.int32) if lead else n

    def runner(plan):
        def go(d):
            arr = CPMArray(d, used, backend="pallas", interpret=interpret)
            cur, outs = executors.run_plan(plan, arr, backend="pallas",
                                           interpret=interpret)
            return cur.data, [o for o in outs if o is not None]
        return jax.jit(go)

    try:
        f_fused, f_eager = runner(fused_plan), runner(eager_plan)
        t_fused = tuning.time_call(lambda: f_fused(data),
                                   reps=_MEASURE_REPS)
        t_eager = tuning.time_call(lambda: f_eager(data),
                                   reps=_MEASURE_REPS)
    except Exception:
        return None
    verdict = {"fuse": bool(t_fused <= t_eager),
               "fused_us": t_fused * 1e6, "eager_us": t_eager * 1e6}
    tuning.store(key, verdict)
    return dict(verdict, params="measured")


def decide(instructions, rows: int, n: int, itemsize: int,
           params: CostParams, *, lead=(), dtype=None,
           interpret: bool | None = None) -> dict:
    """The scheduler's per-run verdict, recorded in the FusionGroup.

    Model-predicted from ``params``; a borderline *calibrated* prediction
    (ratio inside ``MEASURE_BAND``) is settled by direct measurement when
    the caller supplies ``dtype``/``interpret`` — see ``_measured_fuse``.
    """
    fused_s, eager_s = group_cost(instructions, rows, n, itemsize, params)
    verdict = {"fuse": bool(fused_s <= FUSE_MARGIN * eager_s),
               "fused_us": fused_s * 1e6,
               "eager_us": eager_s * 1e6,
               "params": params.source}
    ratio = fused_s / eager_s if eager_s > 0 else float("inf")
    if (params.source == "calibrated" and dtype is not None
            and interpret is not None
            and MEASURE_BAND[0] <= ratio <= MEASURE_BAND[1]):
        measured = _measured_fuse(instructions, lead, n, dtype, interpret)
        if measured is not None:
            verdict = measured
    return verdict
