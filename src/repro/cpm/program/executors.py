"""Per-backend execution of CPM programs.

Three executors, one contract (bit-identical to eager dispatch):

  * ``reference`` — replays every instruction unfused through the ordinary
    ``CPMArray`` method (the oracle).  Batched devices with per-row operands
    replay under ``jax.vmap`` over rows; this is also the eager path the
    recorder uses, so recording and reference execution cannot diverge.
  * ``pallas``    — each *fused* group lowers to ONE
    ``cpm_kernels.fused_stream`` mega-kernel launch: the row block loads
    into VMEM once and every instruction in the group reads/writes it
    there; only group boundaries (reductions, sort, drains) pay another
    launch.
  * ``mesh``      — maps each group's instructions over shards through the
    mesh backend's shard_map collectives; ops outside the mesh op-table
    entry fall back to the reference lowering (the table's
    pin-compatibility contract is per-op).

Operand layout is described once (``_RANKS``): scalars are rank 0, needle/
template/values vectors rank 1.  An operand whose leading dims equal the
device batch shape is *per-row* — the vmap axis in the reference replay and
a per-row ``(R, k)`` block in the mega-kernel; anything else broadcasts.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

import jax
import jax.numpy as jnp

from repro.kernels.cpm_kernels import FUSED_PRODUCERS

from ..optable import OP_TABLE
from . import ir
from .ir import DERIVED_METHODS as _DERIVED

#: ops that leave a value (mask / SAD / filtered flags) rather than a new
#: buffer state — each gets its own output ref in the mega-kernel.  Derived
#: from the kernel's table so the two views cannot drift (a mismatch would
#: silently drop producer outputs in the zip below).
PRODUCERS = frozenset(FUSED_PRODUCERS)

#: operand name -> rank (0 scalar, 1 vector) per recordable method; params
#: missing here (static ints, op strings, tap tuples) never map over rows
_RANKS: dict[str, dict[str, int]] = {
    "activate": {"start": 0, "end": 0, "carry": 0},
    "shift": {"start": 0, "end": 0, "fill": 0},
    "insert": {"pos": 0, "values": 1},
    "delete": {"pos": 0, "fill": 0},
    "truncate": {"new_len": 0},
    "compare": {"datum": 0, "mask": 0},
    "count": {"datum": 0, "mask": 0},
    "substring_match": {"needle": 1},
    "find_all": {"needle": 1},
    "template_match": {"template": 1},
    "stencil": {},
}

#: move ops read ``used_len`` inside roll/select masks — their unbatched
#: lowerings are only row-correct, so batched devices always vmap
_VMAP_ALWAYS = frozenset({"shift", "insert", "delete"})


def _is_per_row(v, rank: int, lead: tuple[int, ...]) -> bool:
    """Per-row iff the operand carries the device's batch dims verbatim —
    an extra leading dim that is not the batch shape (e.g. ``(1, k)`` on a
    ``(2, n)`` device) must NOT be silently split across rows."""
    if v is None or not lead:
        return False
    shape = jnp.shape(v)
    return (len(shape) == len(lead) + rank
            and tuple(shape[:len(lead)]) == tuple(lead))


def _per_row_operands(instr: ir.Instruction, lead) -> bool:
    ranks = _RANKS.get(instr.op, {})
    return any(_is_per_row(instr.operands.get(k), r, lead)
               for k, r in ranks.items())


# ---------------------------------------------------------------------------
# single-instruction replay (reference / any eager backend)
# ---------------------------------------------------------------------------

def apply_instruction(arr, instr: ir.Instruction, backend: str | None = None,
                      interpret: bool | None = None):
    """Execute one instruction eagerly on ``backend`` (default: the
    array's).  Falls back to reference when the forced backend has no table
    entry for the op — per-op pin compatibility, never an error mid-stream."""
    bk = backend or arr.backend
    spec = OP_TABLE.get(_DERIVED.get(instr.op, instr.op))
    if bk not in ("reference", "auto") and spec is not None \
            and bk not in spec.backends:
        bk = "reference"
    kw = {"backend": bk}
    if interpret is not None:
        kw["interpret"] = interpret
    a = dataclasses.replace(arr, **kw)
    lead = arr.batch_shape
    if lead and (instr.op in _VMAP_ALWAYS or _per_row_operands(instr, lead)):
        return _apply_rows(a, instr)
    with ir.suspended():
        return getattr(a, instr.op)(**instr.operands)


def _apply_rows(a, instr: ir.Instruction):
    """Row-wise vmap replay of one instruction on a batched device."""
    from ..array import CPMArray

    lead, n = a.batch_shape, a.n
    r = math.prod(lead)
    data = a.data.reshape(r, n)
    ul = jnp.broadcast_to(jnp.asarray(a.used_len, jnp.int32), lead).reshape(r)
    ranks = _RANKS.get(instr.op, {})
    mapped: dict[str, jax.Array] = {}
    shared = dict(instr.operands)
    for name, rank in ranks.items():
        v = instr.operands.get(name)
        if _is_per_row(v, rank, lead):
            va = jnp.asarray(v)
            mapped[name] = va.reshape(r, *va.shape[len(lead):])
            del shared[name]
    names = tuple(mapped)

    def one(d, u, *mv):
        row = CPMArray(d, u, a.backend, a.interpret)
        with ir.suspended():
            return getattr(row, instr.op)(**dict(shared, **dict(zip(names, mv))))

    out = jax.vmap(one)(data, ul, *[mapped[k] for k in names])
    if isinstance(out, CPMArray):
        return dataclasses.replace(out, data=out.data.reshape(*lead, n),
                                   used_len=out.used_len.reshape(lead))
    return jax.tree_util.tree_map(
        lambda x: x.reshape(*lead, *x.shape[1:]), out)


# ---------------------------------------------------------------------------
# plan execution
# ---------------------------------------------------------------------------

def run_plan(plan, arr, backend: str | None = None,
             interpret: bool | None = None):
    """Execute a scheduled plan; returns ``(final_array, outputs)``.

    Only ``fused`` groups on the pallas backend take the mega-kernel
    path; ``eager`` groups (fusable runs the cost model rejected) and
    ``boundary`` groups replay per-op — same instructions, bit-identical
    results, just a different launch structure."""
    from .. import backends as B

    bk = backend or arr.backend
    if bk == "auto":
        bk = B.auto_backend_name(arr.data)
    outputs: list = [None] * len(plan.program)
    cur = arr
    for group in plan.groups:
        if group.kind == "fused" and bk == "pallas":
            cur, produced = _run_fused_pallas(cur, group, interpret)
            for idx, val in produced:
                outputs[idx] = val
            continue
        for idx, instr in zip(group.indices, group.instructions):
            res = apply_instruction(cur, instr, backend=bk,
                                    interpret=interpret)
            if type(res) is type(cur):
                cur = res
            else:
                outputs[idx] = res
    return cur, outputs


# ---------------------------------------------------------------------------
# the pallas fused-group lowering
# ---------------------------------------------------------------------------

#: don't bother timing row blockings below this problem size — the launch
#: count is tiny and tuning would cost more than it can ever return
_TUNE_MIN_ROWS = 4
_TUNE_MIN_ELEMS = 1 << 15


def _blockr_candidates(r: int) -> list[int]:
    return sorted({br for br in (1, 8, 32, r) if 1 <= br <= r})


def _fused_block_r(descs, operands, data, ul, r, n, backend) -> int:
    """Autotuned rows-per-grid-step for one fused stream, cached per
    (op-stream-signature, shape, dtype, backend) with a JSON spill.

    The key depends only on static shape/dtype facts, so a traced caller
    still *reads* decisions made earlier — but candidates are only ever
    timed outside a trace (``tuning.measurable``), on concrete zeros of
    the recorded shapes; the winner is a static Python int baked into
    the pallas grid.
    """
    from .. import tuning

    if r < _TUNE_MIN_ROWS or r * n < _TUNE_MIN_ELEMS:
        return 1
    cands = _blockr_candidates(r)
    if len(cands) < 2:
        return 1
    sig = hashlib.md5(repr(descs).encode()).hexdigest()[:12]
    key = (f"blockr:{'+'.join(op for op, _, _ in descs)}:{sig}"
           f"|{r}x{n}|{jnp.dtype(data.dtype).name}"
           f"|{tuning.backend_key(backend.interpret)}")
    cached = tuning.lookup(key)
    if cached is not None:
        return int(cached)
    if not tuning.tuning_enabled() or not tuning.measurable():
        return 1
    datz = tuning.synth((r, n), data.dtype)
    ulz = tuning.synth((r,), jnp.int32)
    opz = tuple(tuning.synth(a.shape, a.dtype) for a in operands)

    def run(br):
        return backend.fused_stream(datz, ulz, descs, opz, block_r=br)

    return int(tuning.pick(key, cands, run, default=1))

def _norm_operand(v, rank: int, lead, r: int, dtype=None):
    """Normalize one dynamic operand to a ``(rows, k)`` kernel input
    (``rows`` is ``r`` per-row or 1 broadcast).  Returns (array, shared)."""
    a = jnp.asarray(v) if dtype is None else jnp.asarray(v, dtype)
    if _is_per_row(a, rank, lead):
        return (a.reshape(r, -1) if rank else a.reshape(r, 1)), False
    if a.ndim != rank:
        raise ValueError(
            f"operand of shape {a.shape} matches neither the shared rank-"
            f"{rank} layout nor the per-row layout {tuple(lead)} + rank-"
            f"{rank} for batch {tuple(lead)}")
    return a.reshape(1, -1), True


def _pack_scalars(values, lead, r, dtype):
    """Scalars that share one kernel ref (start/end/carry): broadcast to a
    common row count and concatenate along the operand axis."""
    parts = [_norm_operand(v, 0, lead, r, dtype) for v in values]
    shared = all(s for _, s in parts)
    rows = 1 if shared else r
    packed = jnp.concatenate(
        [jnp.broadcast_to(a, (rows, 1)) for a, _ in parts], axis=1)
    return packed, shared


def _lower(instr: ir.Instruction, dtype, n: int, lead, r: int):
    """Instruction -> (static descriptor, operand arrays, all_shared)."""
    op, ops = instr.op, instr.operands
    if op == "activate":
        packed, shared = _pack_scalars(
            [ops["start"], ops["end"], ops["carry"]], lead, r, jnp.int32)
        return (op, ()), [packed], shared
    if op == "shift":
        se, shared = _pack_scalars([ops["start"], ops["end"]], lead, r,
                                   jnp.int32)
        statics = (("shift", int(ops["shift"])),
                   ("has_fill", ops["fill"] is not None))
        opnds = [se]
        if ops["fill"] is not None:
            f, fs = _norm_operand(ops["fill"], 0, lead, r, dtype)
            opnds.append(f)
            shared = shared and fs
        return (op, statics), opnds, shared
    if op == "insert":
        values = jnp.asarray(ops["values"], dtype)
        k = values.shape[-1]
        pos, ps = _norm_operand(ops["pos"], 0, lead, r, jnp.int32)
        vals, vs = _norm_operand(values, 1, lead, r, dtype)
        return (op, (("k", int(k)),)), [pos, vals], ps and vs
    if op == "delete":
        pos, ps = _norm_operand(ops["pos"], 0, lead, r, jnp.int32)
        fill, fs = _norm_operand(ops["fill"], 0, lead, r, dtype)
        return (op, (("k", int(ops["k"])),)), [pos, fill], ps and fs
    if op == "truncate":
        nl, s = _norm_operand(ops["new_len"], 0, lead, r, jnp.int32)
        return (op, ()), [nl], s
    if op == "compare":
        has_mask = ops.get("mask") is not None
        if has_mask:
            # eager: x = data & mask (promoting), d = asarray(datum,
            # self.dtype) & mask — keep the mask in the promoted dtype so
            # the in-kernel `x & m` / `d & m` promote identically
            d, ds = _norm_operand(jnp.asarray(ops["datum"], dtype), 0,
                                  lead, r)
            # result_type honors weak python scalars exactly like `& mask`
            mct = jnp.result_type(dtype, ops["mask"])
            m, ms = _norm_operand(ops["mask"], 0, lead, r, mct)
            statics = (("op", ops["op"]), ("has_mask", True),
                       ("ct", jnp.dtype(mct).name))
            return (op, statics), [d, m], ds and ms
        ct = jnp.promote_types(dtype, jnp.asarray(ops["datum"]).dtype)
        d, ds = _norm_operand(ops["datum"], 0, lead, r, ct)
        statics = (("op", ops["op"]), ("has_mask", False),
                   ("ct", jnp.dtype(ct).name))
        return (op, statics), [d], ds
    if op == "substring_match":
        needle = jnp.asarray(ops["needle"], dtype)
        nee, s = _norm_operand(needle, 1, lead, r, dtype)
        statics = (("m", int(needle.shape[-1])), ("where", ops["where"]))
        return (op, statics), [nee], s
    if op == "template_match":
        template = jnp.asarray(ops["template"])
        t, s = _norm_operand(template, 1, lead, r)
        statics = (("m", int(template.shape[-1])),
                   ("mask_tail", bool(ops["mask_tail"])))
        return (op, statics), [t], s
    if op == "stencil":
        statics = (("taps", tuple(float(t) for t in ops["taps"])),
                   ("wrap", bool(ops["wrap"])))
        return (op, statics), [], True
    raise NotImplementedError(f"no mega-kernel lowering for op {op!r}")


def _run_fused_pallas(arr, group, interpret):
    """One fused group -> one ``fused_stream`` pallas_call."""
    from .. import backends as B

    lead, n = arr.batch_shape, arr.n
    r = math.prod(lead) if lead else 1
    data = arr.data.reshape(r, n)
    ul = jnp.broadcast_to(jnp.asarray(arr.used_len, jnp.int32),
                          lead or ()).reshape(r)
    itp = interpret if interpret is not None else arr.interpret
    backend = B.get_backend("pallas", interpret=itp)

    descs, operands, meta = [], [], []
    for idx, instr in zip(group.indices, group.instructions):
        (op, statics), opnds, all_shared = _lower(instr, arr.data.dtype, n,
                                                  lead, r)
        # the operand count rides in the static descriptor so the kernel's
        # ref routing has exactly one source of truth (this lowering)
        descs.append((op, statics, len(opnds)))
        operands.extend(opnds)
        if instr.op in PRODUCERS:
            meta.append((idx, instr.op, all_shared))
    descs, operands = tuple(descs), tuple(operands)
    block_r = _fused_block_r(descs, operands, data, ul, r, n, backend)
    out_x, out_ul, prods = backend.fused_stream(
        data, ul, descs, operands, block_r=block_r)

    mutates = any(i.op in ("shift", "insert", "delete", "truncate")
                  for i in group.instructions)
    if mutates:
        new = dataclasses.replace(
            arr, data=out_x.reshape(*lead, n) if lead else out_x.reshape(n),
            used_len=out_ul.reshape(lead) if lead else out_ul.reshape(()))
    else:                       # producers only: device state untouched —
        new = arr               # keep the caller's used_len layout

    produced = []
    for (idx, op, all_shared), raw in zip(meta, prods):
        if op in ("activate", "compare", "substring_match"):
            raw = raw.astype(bool)
        if op == "activate" and all_shared:
            out = raw[0]        # eager activate is batch-free: one (n,) mask
        elif lead:
            out = raw.reshape(*lead, n)
        else:
            out = raw.reshape(n)
        produced.append((idx, out))
    return new, produced
