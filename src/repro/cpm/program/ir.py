"""The CPM program IR and the method-call tracer.

A :class:`CPMProgram` is a linear stream of :class:`Instruction`\\ s over ONE
memory device (the paper's single broadcast stream): each instruction is a
``CPMArray`` method name plus its *named* operands, captured at record time.
Operands may be concrete arrays or tracers — under ``jax.jit`` a program is
recorded once per trace and its scheduled execution lowers into the enclosing
compiled program.

Recording is transparent: inside ``with record() as prog:`` every wrapped
``CPMArray`` method still returns its real (eagerly computed) result — via
the *reference* executor, so no device kernels launch at record time — while
appending the instruction to ``prog``.  Data-dependent control flow on those
results is allowed but is NOT captured in the program (same contract as any
tracer).  Nested internal calls (``count`` → ``compare``) record only the
outermost method.

This module owns only the IR and the recorder state; scheduling lives in
``scheduler.py`` and execution in ``executors.py`` (imported lazily to keep
the package import-cycle-free under ``repro.cpm``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import inspect
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class Instruction:
    """One broadcast instruction: an op-table entry plus named operands."""

    op: str                           # CPMArray method name
    operands: dict[str, Any]          # parameter name -> value (arrays OK)

    def __repr__(self):  # operand values may be tracers — keep repr short
        args = ", ".join(f"{k}={_short(v)}" for k, v in self.operands.items())
        return f"{self.op}({args})"


def _short(v) -> str:
    shape = getattr(v, "shape", None)
    if shape is not None and shape != ():
        return f"<{getattr(v.dtype, 'name', '?')}{list(shape)}>"
    return repr(v)


@dataclass
class CPMProgram:
    """A recorded (or hand-built) instruction stream over one device.

    The IR is strictly linear: instruction ``i+1`` applies to the device
    state instruction ``i`` left behind.  The recorder enforces this —
    calling a method on a stale receiver (anything but the current head of
    the stream) raises instead of silently replaying against the wrong
    state.  Operands are captured **by value** (standard trace semantics,
    like a closure under ``jax.jit``): re-running a plan on a *different*
    device reuses the recorded operand values, so an operand derived from
    a recorded intermediate result does not recompute for the new data.
    """

    instructions: list[Instruction] = field(default_factory=list)
    #: the device state the next recorded instruction must apply to
    _head: Any = field(default=None, repr=False, compare=False)

    def __len__(self):
        return len(self.instructions)

    def __iter__(self):
        return iter(self.instructions)

    def append(self, op: str, /, **operands) -> "CPMProgram":
        """Explicit builder: append one instruction (chainable).

        ``op`` is positional-only so operand keywords can never collide
        with it (``append("compare", datum=40, op="lt")`` works).  Operand
        names are validated against the recorded method's signature and
        defaults are applied, so explicitly built and traced programs
        lower identically.
        """
        sig = _SIGNATURES.get(op)
        if sig is not None:
            bound = sig.bind(None, **operands)      # None stands in for self
            bound.apply_defaults()
            operands = dict(bound.arguments)
            operands.pop("self")
        self.instructions.append(Instruction(op, operands))
        return self

    # -- whole-program cost model (delegates to the scheduler) --------------
    def steps_report(self, n: int, section: int | None = None) -> dict:
        """Per-instruction + total concurrent-step counts at device size
        ``n`` — ``CPMArray.steps_report`` extended to whole programs.

        Telemetry hook: every report also feeds the process-global cycle
        ledger (``repro.obs.cycles``), so scheduled programs' predicted
        cycles accumulate per op family next to any jaxpr-measured trip
        counts an audit records — the live model-vs-measured drift
        metric.  Host-side accounting only; ``REPRO_OBS=0`` skips it."""
        from . import scheduler
        per = [(f"{i}:{ins.op}",
                scheduler.instruction_steps(ins, n, section=section))
               for i, ins in enumerate(self.instructions)]
        report = dict(per)
        report["total"] = sum(s for _, s in per)
        from repro.obs import cycles as _obs_cycles
        _obs_cycles.note_report(self, n, report)
        return report

    def run(self, array, backend: str | None = None,
            interpret: bool | None = None):
        """Schedule and execute against ``array``; returns
        ``(final_array, outputs)`` with ``outputs[i]`` the value produced by
        instruction ``i`` (``None`` for pure buffer transforms)."""
        from . import scheduler
        return scheduler.schedule(self).run(array, backend=backend,
                                            interpret=interpret)


# ---------------------------------------------------------------------------
# recorder state + the method decorator
# ---------------------------------------------------------------------------

#: derived CPMArray methods -> the OP_TABLE op doing the work (each adds
#: one Rule-6 count/drain step on top) — the single definition shared by
#: the cost model (scheduler) and backend-fallback routing (executors)
DERIVED_METHODS = {"count": "compare", "find_all": "substring_match"}

_STATE: dict[str, Any] = {"program": None, "suspend": 0}

#: op name -> the decorated CPMArray method's signature (self included) —
#: lets the explicit builder bind/validate operands exactly like the tracer
_SIGNATURES: dict[str, inspect.Signature] = {}


def active_program() -> CPMProgram | None:
    """The open recorder, unless recording is suspended (internal calls)."""
    return None if _STATE["suspend"] else _STATE["program"]


@contextlib.contextmanager
def suspended():
    """Temporarily stop recording (nested method calls, executor replay)."""
    _STATE["suspend"] += 1
    try:
        yield
    finally:
        _STATE["suspend"] -= 1


@contextlib.contextmanager
def record():
    """``with cpm.record() as prog:`` — trace CPMArray method calls.

    One recorder may be open at a time (the device executes one broadcast
    stream); nesting raises.  The stream must be linear (see
    :class:`CPMProgram`): chain each transform off the previous result, and
    remember that operands are captured by value — replaying the plan on a
    different device does not recompute operands that were derived from
    recorded intermediates.
    """
    if _STATE["program"] is not None:
        raise RuntimeError("cpm.record() does not nest: a recording is "
                           "already active")
    prog = CPMProgram()
    _STATE["program"] = prog
    try:
        yield prog
    finally:
        _STATE["program"] = None


def recordable(op: str):
    """Decorator for ``CPMArray`` methods: the dispatch hook of the tracer.

    Outside a recording the method runs untouched.  Inside, the call is
    appended as an :class:`Instruction` (operands bound to parameter names,
    defaults applied) and the result is computed through the reference
    executor with recording suspended — real values out, no device kernels
    in the trace, single execution path shared with replay.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        _SIGNATURES[op] = sig

        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            prog = active_program()
            if prog is None:
                return fn(self, *args, **kwargs)
            # linearity guard: replay applies instructions in sequence, so
            # a call on anything but the stream's current head would replay
            # against different state than it ran on — raise, don't diverge
            head = prog._head
            if head is not None and not (self.data is head.data
                                         and self.used_len is head.used_len):
                raise RuntimeError(
                    f"non-linear recording: {op}() called on a device that "
                    "is not the current head of the recorded stream (the "
                    "result of the last recorded transform).  Record one "
                    "linear chain per program, or build branching pipelines "
                    "as separate programs.")
            bound = sig.bind(self, *args, **kwargs)
            bound.apply_defaults()
            operands = dict(bound.arguments)
            operands.pop("self")
            instr = Instruction(op, operands)
            prog.instructions.append(instr)
            from . import executors
            with suspended():
                out = executors.apply_instruction(self, instr,
                                                  backend="reference")
            # restore the caller's device identity on array results so the
            # chained stream keeps its backend/interpret routing hints
            if type(out) is type(self):
                out = dataclasses.replace(out, backend=self.backend,
                                          interpret=self.interpret)
                prog._head = out            # transforms advance the head
            elif head is None:
                prog._head = self           # first call pins the device
            return out
        return wrapper
    return deco
