"""repro.cpm.program — instruction streams as first-class values.

The paper's host does not call the memory one op at a time: it broadcasts an
*instruction stream* and the memory executes it internally (§3–§4), which is
what removes the per-op bus round-trip.  This package gives the repo the same
shape:

  * :class:`~repro.cpm.program.ir.CPMProgram` — a linear IR over one memory
    device whose instructions are `OP_TABLE` entries plus recorded operands.
    Build one explicitly (:meth:`CPMProgram.append`) or record it from
    ordinary ``CPMArray`` method calls::

        with cpm.record() as prog:
            dev.compare(threshold, "ge")
            dev = dev.insert(pos, values)

  * :func:`~repro.cpm.program.scheduler.schedule` — the fusing scheduler:
    partitions the stream into :class:`FusionGroup`\\ s.  Maximal runs of
    elementwise/local ops (``fusable=True`` in the op table: activate,
    shift/insert/delete/truncate, compare, substring/template match, stencil)
    become ONE fused Pallas mega-kernel that keeps the section resident in
    VMEM across instructions; reductions (§7 two-phase, §8 super ops), sort
    and Rule-6 drains are group boundaries.

  * :mod:`~repro.cpm.program.executors` — per-backend execution:
    ``reference`` replays each instruction unfused (the oracle), ``pallas``
    launches one ``fused_stream`` kernel per fused group, ``mesh`` maps
    group instructions over shards via the mesh backend's collectives.
    All three are differential-tested bit-identical to eager dispatch.

  * the static cycle-cost model — :meth:`CPMProgram.steps_report` /
    :func:`~repro.cpm.program.scheduler.program_steps` sum the
    ``OP_TABLE`` step formulas over a whole program;
    ``scan_structured_steps`` is asserted against jaxpr-measured trip
    counts of the reference lowering (``benchmarks/run.py program_fusion``).
"""

from .ir import CPMProgram, Instruction, record
from .scheduler import (FusionGroup, FusionPlan, instruction_steps,
                        program_steps, scan_structured_steps, schedule)
from .executors import apply_instruction, run_plan
from .introspect import count_pallas_calls, scan_trip_count
from .costmodel import CostParams, group_cost, roofline_params

__all__ = [
    "CPMProgram", "Instruction", "record",
    "FusionGroup", "FusionPlan", "schedule",
    "instruction_steps", "program_steps", "scan_structured_steps",
    "apply_instruction", "run_plan",
    "count_pallas_calls", "scan_trip_count",
    "CostParams", "group_cost", "roofline_params",
]
