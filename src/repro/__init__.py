"""repro — Concurrent Processing Memory (Wang, 2006) as a production
TPU-native JAX training/serving framework.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
