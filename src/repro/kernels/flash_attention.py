"""Blocked online-softmax attention (TPU Pallas).

The framework's compute hot-spot.  TPU-native tiling: q blocks of (block_q,
head_dim) stream kv blocks of (block_k, head_dim) through VMEM, carrying the
running max / denominator / accumulator in VMEM scratch across the innermost
grid dimension (the canonical TPU flash schedule — grid iteration is
sequential on TPU, so the kv axis is the in-order accumulation axis).

Supports causal masking, local (sliding-window) masking and GQA head
grouping via the kv BlockSpec index map.  Validated against ``ref.py`` in
interpret mode (this container is CPU-only; TPU is the lowering target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                          # masked lanes: exp(-1e30)→0
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KVH, Skv, D) with H % KVH == 0.

    Returns (B, H, Sq, D).  ``window`` masks cols <= rows - window (local
    attention, RecurrentGemma-style).  ``interpret=True`` runs the kernel
    body on CPU; on TPU pass interpret=False.
    """
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    scale = d ** -0.5

    grid = (b, h, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
