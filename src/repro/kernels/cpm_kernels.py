"""Pallas TPU kernels for the paper's in-memory algorithms.

Chip-scale CPM: the VMEM block is the PE array (VREG lanes = PEs), the
kernel body is the broadcast instruction stream (Rule 5), intra-block shifts
are neighbor reads (Rule 7).

Kernels:
  * ``activate``        — §3.3 Rule-4 general decoder (range + carry mask).
  * ``shift_range``     — §4.1 concurrent range move (roll + select in VMEM).
  * ``oddeven_sort``    — §7.7 local-exchange sort, N compare-exchange cycles
                          entirely in VMEM (used by MoE routing).
  * ``compare``         — §6.1 broadcast-datum compare, one VPU cycle.
  * ``histogram``       — §6.3 M-bin histogram, one compare+count per edge;
                          row-batched and HBM-tiled (rows x sections grid).
  * ``section_sum``     — §7.4 two-phase reduction: concurrent per-section
                          sums (phase 1, one grid step per section block)
                          accumulated across the grid (phase 2).  Batched:
                          ``(R, N)`` rows reduce in ONE launch over a
                          (rows, sections) grid with a per-row accumulator,
                          and N may exceed a single VMEM block (sections
                          stream from HBM).
  * ``section_limit``   — §7.5 global max/min with the same structure.
  * ``super_sum``       — §8 super-connected sum: per-section partials kept
                          in a VMEM scratch line, combined by a log-depth
                          pairwise tree (Fig. 16 skip links) instead of the
                          serial phase-2 march.
  * ``super_limit``     — §8 log-depth global max/min.
  * ``template_match``  — §7.6 sliding SAD, ~M shift-accumulate cycles.
  * ``substring_match`` — §5 streaming needle match with neighbor carry.
  * ``stencil``         — §7.3 tap algebra, ~M shift-multiply-accumulate
                          (``wrap=False`` zero-pads the row ends instead of
                          wrapping, matching the canonical `repro.cpm`
                          semantics).
  * ``compact``         — §4.2 stable pack of flagged items: a log-depth
                          Hillis-Steele cumsum over the keep flags followed
                          by a log-depth per-lane lower-bound gather —
                          ~2·log2(N) concurrent steps, bit-identical to the
                          reference argsort pack.
  * ``gather_rows`` / ``scatter_rows`` — paged-row movement for the bank
                          pool (`repro.cpm.pool`): dynamic row indices ride
                          in scalar-prefetch so each grid step DMAs exactly
                          one (1, N) page between HBM rows and VMEM.

All take ``interpret=`` with a ``None`` = auto default — compiled on TPU,
Pallas interpreter elsewhere — the same rule ``CPMArray`` applies, so a
kernel called directly on a real TPU never silently runs interpreted.
These kernels are the ``pallas`` backend of ``repro.cpm`` — prefer driving
them through ``CPMArray``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def resolve_interpret(interpret: bool | None) -> bool:
    """The one interpret auto rule (shared with ``CPMArray`` and
    ``PallasBackend``): run kernel bodies compiled on TPU, under the Pallas
    interpreter everywhere else.  ``None`` means auto."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


# ---------------------------------------------------------------------------
# §3.3 Rule 4 — the general decoder
# ---------------------------------------------------------------------------

def _activate_vals(idx, start, end, carry):
    """Rule-4 general-decoder predicate — the one value-level body shared
    by the standalone kernel and the fused instruction stream."""
    carry = jnp.maximum(carry, 1)
    return (idx >= start) & (idx <= end) & ((idx - start) % carry == 0)


def _activate_kernel(p_ref, o_ref, *, n: int):
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    mask = _activate_vals(idx, p_ref[0, 0], p_ref[0, 1], p_ref[0, 2])
    o_ref[...] = mask.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def activate(n: int, start, end, carry=1, *, interpret: bool | None = None) -> jax.Array:
    """Rule-4 activation mask of length ``n`` as one VPU predicate cycle."""
    params = jnp.stack([jnp.asarray(start, jnp.int32),
                        jnp.asarray(end, jnp.int32),
                        jnp.asarray(carry, jnp.int32)]).reshape(1, 3)
    out = pl.pallas_call(
        functools.partial(_activate_kernel, n=n),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int8),
        interpret=resolve_interpret(interpret),
    )(params)
    return out[0].astype(bool)


# ---------------------------------------------------------------------------
# §4.1 — concurrent range move
# ---------------------------------------------------------------------------

def _shift_vals(x, idx, start, end, shift: int, n: int, fill=None):
    """§4.1 range move of a resident block — the one value-level body shared
    by the standalone kernel and the fused instruction stream."""
    src_mask = (idx >= start) & (idx <= end)
    moved = jnp.roll(x, shift, axis=-1)
    dst_mask = jnp.roll(src_mask, shift, axis=-1)
    if shift > 0:
        dst_mask = dst_mask & (idx >= shift)
    elif shift < 0:
        dst_mask = dst_mask & (idx < n + shift)
    out = jnp.where(dst_mask, moved, x)
    if fill is not None:
        out = jnp.where(src_mask & ~dst_mask, fill, out)
    return out


def _shift_range_kernel(x_ref, p_ref, f_ref, o_ref, *, n: int, shift: int,
                        has_fill: bool):
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    o_ref[...] = _shift_vals(x, idx, p_ref[0, 0], p_ref[0, 1], shift, n,
                             f_ref[0, 0] if has_fill else None)


@functools.partial(jax.jit, static_argnames=("shift", "interpret"))
def shift_range(x: jax.Array, start, end, shift: int = 1, fill=None, *,
                interpret: bool | None = None) -> jax.Array:
    """Move the [start, end] range of every (R, N) row by ``shift`` places.

    Same semantics as ``repro.cpm.reference.movable.shift_range`` — vacated
    slots keep old content unless ``fill`` is given; content crossing the
    physical ends is dropped.  One concurrent roll+select cycle in VMEM.
    """
    r, n = x.shape
    params = jnp.stack([jnp.asarray(start, jnp.int32),
                        jnp.asarray(end, jnp.int32)]).reshape(1, 2)
    fill_arr = jnp.asarray(0 if fill is None else fill, x.dtype).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_shift_range_kernel, n=n, shift=shift,
                          has_fill=fill is not None),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, 2), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), x.dtype),
        interpret=resolve_interpret(interpret),
    )(x, params, fill_arr)


# ---------------------------------------------------------------------------
# §7.7 odd-even transposition sort (row-wise)
# ---------------------------------------------------------------------------

def _oddeven_kernel(x_ref, o_ref, *, n: int, steps: int):
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

    def body(i, x):
        is_left = (idx % 2) == (i % 2)
        partner = jnp.clip(jnp.where(is_left, idx + 1, idx - 1), 0, n - 1)
        px = jnp.take_along_axis(x, partner, axis=1)
        out = jnp.where(is_left, jnp.minimum(x, px), jnp.maximum(x, px))
        solo = (partner == idx) | (is_left & (idx == n - 1))
        return jnp.where(solo, x, out)

    o_ref[...] = jax.lax.fori_loop(0, steps, body, x_ref[...])


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def oddeven_sort(x: jax.Array, steps: int | None = None, *,
                 interpret: bool | None = None) -> jax.Array:
    """Row-wise ascending sort of (R, N): N odd-even cycles in VMEM."""
    r, n = x.shape
    steps = n if steps is None else steps
    return pl.pallas_call(
        functools.partial(_oddeven_kernel, n=n, steps=steps),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), x.dtype),
        interpret=resolve_interpret(interpret),
    )(x)


# ---------------------------------------------------------------------------
# §7.4 two-phase sectioned sum (row-batched, HBM-tiled)
# ---------------------------------------------------------------------------

def _pad_rows(x: jax.Array, section: int, fill=0):
    """(..., N) -> ((R, N_padded), nsec, unflatten-to-leading-dims)."""
    lead = x.shape[:-1]
    n = x.shape[-1]
    pad = (-n) % section
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                    constant_values=fill)
    x2 = x.reshape(-1, x.shape[-1])
    return x2, x2.shape[-1] // section, (lambda out: out.reshape(lead))


def _acc_dtype(dtype):
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _section_sum_kernel(x_ref, o_ref, acc_ref):
    j = pl.program_id(1)                    # section index (innermost)

    @pl.when(j == 0)
    def _():                                # fresh accumulator per row
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # phase 1: concurrent in-section reduction of this VMEM block
    acc_ref[...] += jnp.sum(x_ref[...].astype(acc_ref.dtype), axis=-1,
                            keepdims=True)

    # phase 2: the running accumulator marches across sections (grid order)
    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("section", "interpret"))
def section_sum(x: jax.Array, section: int = 1024, *,
                interpret: bool | None = None) -> jax.Array:
    """Two-phase sum of every ``(..., N)`` row; section = VMEM block size.

    ONE kernel launch for any batch shape: the grid is (rows, sections)
    with a per-row VMEM accumulator, and sections stream from HBM so N may
    exceed a single VMEM block.  Integer inputs accumulate in int32 (exact,
    matching ``jnp.sum`` semantics); floats accumulate in float32.
    """
    acc_dtype = _acc_dtype(x.dtype)
    xs, nsec, unflatten = _pad_rows(x, section)
    r = xs.shape[0]
    out = pl.pallas_call(
        _section_sum_kernel,
        grid=(r, nsec),
        in_specs=[pl.BlockSpec((1, section), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), acc_dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), acc_dtype)],
        interpret=resolve_interpret(interpret),
    )(xs)
    return unflatten(out).astype(jnp.promote_types(x.dtype, acc_dtype))


# ---------------------------------------------------------------------------
# §6.1 broadcast compare + §6.3 histogram
# ---------------------------------------------------------------------------

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "gt": lambda a, b: a > b,
    "le": lambda a, b: a <= b,
    "ge": lambda a, b: a >= b,
}


def _compare_kernel(x_ref, d_ref, o_ref, *, op: str):
    o_ref[...] = _CMP[op](x_ref[...], d_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def compare(x: jax.Array, datum, op: str = "eq", *,
            interpret: bool | None = None) -> jax.Array:
    """(R, N) rows vs a broadcast datum: one concurrent VPU compare.

    Mixed dtypes promote (never truncate toward ``x.dtype``): comparing int
    rows against 2.5 compares against 2.5, matching the reference oracle.
    """
    ct = jnp.promote_types(x.dtype, jnp.asarray(datum).dtype)
    x = x.astype(ct)
    r, n = x.shape
    d = jnp.asarray(datum, ct).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_compare_kernel, op=op),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.int8),
        interpret=resolve_interpret(interpret),
    )(x, d)
    return out.astype(bool)


def _histogram_kernel(x_ref, e_ref, o_ref, acc_ref, *, m: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (1, section)
    # one broadcast compare + Rule-6 parallel count per section edge
    below = (x < e_ref[...].reshape(m + 1, 1)).astype(jnp.int32)
    cum = jnp.sum(below, axis=-1)                    # (M+1,)
    acc_ref[...] += (cum[1:] - cum[:-1]).reshape(1, m)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("section", "interpret"))
def histogram(x: jax.Array, edges: jax.Array, section: int = 1024, *,
              interpret: bool | None = None) -> jax.Array:
    """(..., N) values x (M+1,) ascending edges -> (..., M) per-row counts
    (§6.3, ~M compare+count cycles).

    Same (rows, sections) grid as the §7.4 reductions: one launch for any
    batch shape, N streamed section-by-section from HBM into VMEM with a
    per-row (1, M) bin accumulator.  Row padding takes the top edge, which
    lands in no ``[e_i, e_{i+1})`` bin.  Mixed dtypes promote (fractional
    edges stay fractional on int data).
    """
    ct = jnp.promote_types(x.dtype, edges.dtype)
    x, edges = x.astype(ct), edges.astype(ct)
    m = edges.shape[-1] - 1
    xs, nsec, _ = _pad_rows(x, section, fill=edges[-1])
    r = xs.shape[0]
    out = pl.pallas_call(
        functools.partial(_histogram_kernel, m=m),
        grid=(r, nsec),
        in_specs=[pl.BlockSpec((1, section), lambda i, j: (i, j)),
                  pl.BlockSpec((1, m + 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((1, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, m), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(xs, edges.reshape(1, m + 1))
    return out.reshape(*x.shape[:-1], m)


# ---------------------------------------------------------------------------
# §7.5 two-phase sectioned limit (global max/min)
# ---------------------------------------------------------------------------

def _section_limit_kernel(x_ref, o_ref, acc_ref, *, mode: str, init):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.full_like(acc_ref, init)

    red = jnp.max if mode == "max" else jnp.min
    cmb = jnp.maximum if mode == "max" else jnp.minimum
    acc_ref[...] = cmb(acc_ref[...],
                       red(x_ref[...].astype(acc_ref.dtype), axis=-1,
                           keepdims=True))

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("section", "mode", "interpret"))
def section_limit(x: jax.Array, section: int = 1024, mode: str = "max", *,
                  interpret: bool | None = None) -> jax.Array:
    """Two-phase max/min of every ``(..., N)`` row (§7.5).

    Same batched (rows, sections) grid as :func:`section_sum`: one launch,
    per-row accumulator, sections streamed from HBM.
    """
    # function-level import: keeps the kernels module import-free of the
    # cpm package at module scope (backends.pallas imports this module)
    from repro.cpm.semantics import limit_identity

    acc_dtype = _acc_dtype(x.dtype)
    fill = limit_identity(acc_dtype, mode)
    xs, nsec, unflatten = _pad_rows(x, section,
                                    fill=limit_identity(x.dtype, mode))
    r = xs.shape[0]
    out = pl.pallas_call(
        functools.partial(_section_limit_kernel, mode=mode, init=fill),
        grid=(r, nsec),
        in_specs=[pl.BlockSpec((1, section), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), acc_dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), acc_dtype)],
        interpret=resolve_interpret(interpret),
    )(xs)
    return unflatten(out).astype(x.dtype)


# ---------------------------------------------------------------------------
# §8 super-connectivity: log-depth combine of the section partials
# ---------------------------------------------------------------------------

def _tree_combine_block(x, k: int, combine, identity):
    """Log-depth pairwise combine of the first ``k`` lanes of a (1, K) block.

    Level ``j`` reads the partner 2**j lanes away — exactly Fig. 16's skip
    links; ceil(log2(k)) unrolled levels leave the full combine in lane 0.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    levels = max(1, (k - 1).bit_length()) if k > 1 else 0
    for j in range(levels):
        stride = 1 << j
        partner = jnp.roll(x, -stride, axis=-1)
        partner = jnp.where(idx + stride < k, partner, identity)
        x = combine(x, partner)
    return x


def _super_kernel(x_ref, o_ref, acc_ref, *, mode: str, nsec: int, identity):
    j = pl.program_id(1)
    red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[mode]
    cmb = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[mode]

    # phase 1: this section's concurrent partial, parked in its scratch lane
    part = red(x_ref[...].astype(acc_ref.dtype), axis=-1, keepdims=True)
    acc_ref[:, pl.ds(j, 1)] = part

    # phase 2: §8 log-depth tree over the section partials (not a march)
    @pl.when(j == nsec - 1)
    def _():
        o_ref[...] = _tree_combine_block(acc_ref[...], nsec, cmb,
                                         identity)[:, :1]


def _super_reduce(x: jax.Array, section: int, mode: str, *, interpret: bool):
    from repro.cpm.semantics import limit_identity

    acc_dtype = _acc_dtype(x.dtype)
    if mode == "sum":
        pad_fill, identity = 0, 0            # python scalars: the kernel body
    else:                                    # must not close over tracers
        pad_fill = limit_identity(x.dtype, mode)
        identity = limit_identity(acc_dtype, mode)
    xs, nsec, unflatten = _pad_rows(x, section, fill=pad_fill)
    r = xs.shape[0]
    out = pl.pallas_call(
        functools.partial(_super_kernel, mode=mode, nsec=nsec,
                          identity=identity),
        grid=(r, nsec),
        in_specs=[pl.BlockSpec((1, section), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), acc_dtype),
        scratch_shapes=[pltpu.VMEM((1, nsec), acc_dtype)],
        interpret=resolve_interpret(interpret),
    )(xs)
    return unflatten(out)


@functools.partial(jax.jit, static_argnames=("section", "interpret"))
def super_sum(x: jax.Array, section: int = 1024, *,
              interpret: bool | None = None) -> jax.Array:
    """§8 super-connected sum of every ``(..., N)`` row: sectioned phase 1,
    log-depth tree phase 2 (~2·log2(N) concurrent steps instead of ~2·√N).
    Same result as :func:`section_sum` (bit-identical for ints)."""
    out = _super_reduce(x, section, "sum", interpret=interpret)
    return out.astype(jnp.promote_types(x.dtype, out.dtype))


@functools.partial(jax.jit, static_argnames=("section", "mode", "interpret"))
def super_limit(x: jax.Array, section: int = 1024, mode: str = "max", *,
                interpret: bool | None = None) -> jax.Array:
    """§8 super-connected max/min of every ``(..., N)`` row (log-depth
    phase 2).  Same result as :func:`section_limit`."""
    return _super_reduce(x, section, mode, interpret=interpret).astype(x.dtype)


# ---------------------------------------------------------------------------
# §7.6 template match (row-wise sliding SAD)
# ---------------------------------------------------------------------------

def _sad_vals(x_f32, t_row, m: int):
    """§7.6 sliding-SAD accumulation on a resident float32 block (shared by
    the standalone kernel and the fused instruction stream); ``t_row`` is a
    (1, M) broadcast or (BR, M) per-row template ref/array."""
    t = t_row[...]

    def body(j, acc):
        shifted = jnp.roll(x_f32, -j, axis=-1)
        tap = jax.lax.dynamic_slice_in_dim(t, j, 1, axis=1)  # (rows, 1)
        return acc + jnp.abs(shifted - tap.astype(jnp.float32))

    return jax.lax.fori_loop(0, m, body, jnp.zeros_like(x_f32))


def _template_kernel(x_ref, t_ref, o_ref, *, m: int):
    o_ref[...] = _sad_vals(x_ref[...].astype(jnp.float32), t_ref, m)


@functools.partial(jax.jit, static_argnames=("interpret",))
def template_match(data: jax.Array, template: jax.Array, *,
                   interpret: bool | None = None) -> jax.Array:
    """(R, N) x (M,) -> (R, N) SAD at every start position (wrapping tail)."""
    r, n = data.shape
    m = template.shape[-1]
    return pl.pallas_call(
        functools.partial(_template_kernel, m=m),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(data, template.reshape(1, -1))


# ---------------------------------------------------------------------------
# §5 substring match (row-wise, match-end semantics)
# ---------------------------------------------------------------------------

def _substring_ends_vals(x, nee_row, m: int, idx):
    """§5 match-END carry chain on a resident block (shared by the
    standalone kernel and the fused instruction stream); ``nee_row`` is a
    (1, M) broadcast or (BR, M) per-row needle ref/array.  Returns int32
    0/1 flags."""
    first = idx == 0
    nee = nee_row[...]

    def body(i, state):
        sym = jax.lax.dynamic_slice_in_dim(nee, i, 1, axis=1)  # (rows, 1)
        hit = (x == sym).astype(jnp.int32)
        shifted = jnp.where(first, 0, jnp.roll(state, 1, axis=-1))
        return jnp.where(i == 0, hit, hit * shifted)

    return jax.lax.fori_loop(0, m, body, jnp.zeros(x.shape, jnp.int32))


def _substring_kernel(x_ref, nee_ref, o_ref, *, m: int, n: int):
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    o_ref[...] = _substring_ends_vals(x_ref[...], nee_ref, m,
                                      idx).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def substring_match(hay: jax.Array, needle: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """(R, N) int rows x (M,) needle -> (R, N) int8 match-end flags."""
    r, n = hay.shape
    m = needle.shape[-1]
    return pl.pallas_call(
        functools.partial(_substring_kernel, m=m, n=n),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.int8),
        interpret=resolve_interpret(interpret),
    )(hay, needle.reshape(1, -1))


# ---------------------------------------------------------------------------
# §7.3 stencil (row-wise tap accumulation)
# ---------------------------------------------------------------------------

def _stencil_kernel(x_ref, o_ref, *, taps: tuple[float, ...], wrap: bool):
    x = x_ref[...].astype(jnp.float32)
    n = x.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    o_ref[...] = _stencil_vals(x, idx, taps, wrap, n)


def _stencil_vals(x, idx, taps: tuple[float, ...], wrap: bool, n: int):
    """§7.3 tap accumulation on a resident float32 block (shared body)."""
    acc = jnp.zeros_like(x)
    c = len(taps) // 2
    for k, w in enumerate(taps):        # unrolled ~M shift-mul-add cycles
        if w == 0:
            continue
        shifted = jnp.roll(x, k - c, axis=-1)
        if not wrap:                    # zero the lanes that wrapped around
            if k - c > 0:
                shifted = jnp.where(idx >= k - c, shifted, 0.0)
            elif k - c < 0:
                shifted = jnp.where(idx < n + (k - c), shifted, 0.0)
        acc = acc + w * shifted
    return acc


@functools.partial(jax.jit, static_argnames=("taps", "wrap", "interpret"))
def stencil(x: jax.Array, taps: tuple[float, ...], *, wrap: bool = True,
            interpret: bool | None = None) -> jax.Array:
    """(R, N) rows filtered by an odd-length tap vector.

    ``wrap=True`` keeps the historical ring semantics (row ends wrap);
    ``wrap=False`` zero-pads the row ends — the canonical `repro.cpm`
    convention (see ``repro.cpm.semantics``).
    """
    r, n = x.shape
    return pl.pallas_call(
        functools.partial(_stencil_kernel, taps=taps, wrap=wrap),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x)


# ---------------------------------------------------------------------------
# §4.2 compact (stable pack, log-depth cumsum-gather)
# ---------------------------------------------------------------------------

def _compact_kernel(x_ref, k_ref, f_ref, o_ref, l_ref, *, n: int):
    x = x_ref[...]                                   # (1, n) row
    keep = k_ref[...]                                # (1, n) int32 0/1 flags
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    # phase 1: inclusive cumsum of the keep flags — a Hillis-Steele doubling
    # tree, ceil(log2(n)) concurrent shift+add cycles (the paper's per-object
    # range moves collapsed into one log-depth rank computation)
    c = keep
    levels = (n - 1).bit_length() if n > 1 else 0
    for b in range(levels):
        stride = 1 << b
        sh = jnp.roll(c, stride, axis=-1)
        c = c + jnp.where(idx >= stride, sh, 0)
    new_len = c[:, n - 1:]                           # (1, 1) survivor count
    # phase 2: src[i] = first j with c[j] >= i+1 (c is monotone, and c
    # increments exactly at kept lanes) — a vectorized lower-bound search,
    # one take_along_axis probe per bit, ~log2(n) more concurrent cycles
    t = idx + 1
    pos = jnp.zeros((1, n), jnp.int32)
    for b in reversed(range(n.bit_length())):
        npos = pos + (1 << b)
        cv = jnp.take_along_axis(c, jnp.clip(npos - 1, 0, n - 1), axis=1)
        pos = jnp.where((npos <= n) & (cv < t), npos, pos)
    gathered = jnp.take_along_axis(x, jnp.clip(pos, 0, n - 1), axis=1)
    o_ref[...] = jnp.where(t <= new_len, gathered, f_ref[0, 0])
    l_ref[...] = new_len


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact(x: jax.Array, keep: jax.Array, fill=0, *,
            interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Stable §4.2 pack of every (R, N) row: kept lanes move to the front
    (order preserved), vacated lanes take ``fill``.  Returns
    ``(compacted (R, N), new_len (R,))``.  ~2·log2(N) concurrent steps —
    bit-identical to ``reference.movable.compact``."""
    r, n = x.shape
    fill_arr = jnp.asarray(fill, x.dtype).reshape(1, 1)
    out, nl = pl.pallas_call(
        functools.partial(_compact_kernel, n=n),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, n), x.dtype),
                   jax.ShapeDtypeStruct((r, 1), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(x, keep.astype(jnp.int32), fill_arr)
    return out, nl[:, 0]


# ---------------------------------------------------------------------------
# paged-row movement (repro.cpm.pool banks)
# ---------------------------------------------------------------------------

def _copy_row_kernel(idx_ref, x_ref, o_ref):
    del idx_ref                                      # consumed by index_map
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(x: jax.Array, idx: jax.Array, *,
                interpret: bool | None = None) -> jax.Array:
    """(R, N) bank x (K,) page indices -> (K, N) gathered rows.

    The index vector rides in scalar-prefetch, so each grid step's BlockSpec
    resolves to the dynamic source row before the body runs — one (1, N)
    page DMA per output row, the paged-KV access pattern."""
    k = idx.shape[0]
    n = x.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, n), lambda i, iref: (iref[i], 0))],
        out_specs=pl.BlockSpec((1, n), lambda i, iref: (i, 0)))
    return pl.pallas_call(
        _copy_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, n), x.dtype),
        interpret=resolve_interpret(interpret),
    )(idx.astype(jnp.int32), x)


def _scatter_row_kernel(inv_ref, d_ref, s_ref, o_ref):
    i = pl.program_id(0)
    o_ref[...] = jnp.where(inv_ref[i] >= 0, s_ref[...], d_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_rows(dst: jax.Array, idx: jax.Array, src: jax.Array, *,
                 interpret: bool | None = None) -> jax.Array:
    """Write ``src`` (K, N) rows into ``dst`` (R, N) at row indices ``idx``
    (K unique pages); untouched rows keep their content.

    Lowered as a gather over destination rows (the inverse page map rides in
    scalar-prefetch): row r reads ``src[inv[r]]`` when some page maps there
    and its own ``dst`` block otherwise — every output block is written
    exactly once, no aliasing or read-modify-write hazard."""
    r, n = dst.shape
    k = idx.shape[0]
    inv = jnp.full((r,), -1, jnp.int32).at[idx].set(
        jnp.arange(k, dtype=jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i, iref: (i, 0)),
                  pl.BlockSpec((1, n),
                               lambda i, iref: (jnp.maximum(iref[i], 0), 0))],
        out_specs=pl.BlockSpec((1, n), lambda i, iref: (i, 0)))
    return pl.pallas_call(
        _scatter_row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, n), dst.dtype),
        interpret=resolve_interpret(interpret),
    )(inv, dst, src)


# ---------------------------------------------------------------------------
# fused instruction streams — one launch for a whole §3–§7 program group
# ---------------------------------------------------------------------------

#: producer ops and their kernel output dtypes (cast to bool by the caller)
FUSED_PRODUCERS = {
    "activate": jnp.int8,
    "compare": jnp.int8,
    "substring_match": jnp.int8,
    "template_match": jnp.float32,
    "stencil": jnp.float32,
}

_FUSED_TRANSFORMS = ("shift", "insert", "delete", "truncate")


def _fused_apply(op: str, statics, x, ul, refs, idx, n: int):
    """Execute one broadcast instruction on the resident (BR, N) block.

    ``x`` is the live buffer block, ``ul`` the §4.2 used-length register
    (a (BR, 1) column) — both stay in VMEM across the whole group.  Every
    dynamic operand ref is read as a column slice ``ref[:, j:j+1]`` whose
    row count is 1 (broadcast) or BR (per-row), so the same body serves
    any row blocking.  Returns ``(x, ul, produced)`` with ``produced``
    None for buffer transforms.  Each branch mirrors the corresponding
    eager lowering exactly (same op order, same dtypes), so the fused
    stream is bit-identical to per-op dispatch.
    """
    s = dict(statics)
    live = idx < ul
    if op == "activate":
        p = refs[0][...]
        mask = _activate_vals(idx, p[:, 0:1], p[:, 1:2], p[:, 2:3])
        return x, ul, jnp.broadcast_to(mask, x.shape).astype(jnp.int8)
    if op == "shift":
        se = refs[0][...]
        fill = refs[1][:, 0:1] if s["has_fill"] else None
        return (_shift_vals(x, idx, se[:, 0:1], se[:, 1:2], s["shift"], n,
                            fill),
                ul, None)
    if op == "insert":
        pos, v, k = refs[0][:, 0:1], refs[1][...], s["k"]
        x = _shift_vals(x, idx, pos, ul - 1, k, n)
        for j in range(k):              # §4.2 broadcast write, unrolled
            x = jnp.where(idx == pos + j, v[:, j:j + 1], x)
        return x, jnp.minimum(ul + k, n), None
    if op == "delete":
        pos, fill, k = refs[0][:, 0:1], refs[1][:, 0:1], s["k"]
        x = _shift_vals(x, idx, pos + k, ul - 1, -k, n)
        x = jnp.where((idx >= ul - k) & (idx < ul), fill, x)
        return x, jnp.maximum(ul - k, 0), None
    if op == "truncate":
        return x, jnp.minimum(ul, refs[0][:, 0:1]), None
    if op == "compare":
        d = refs[0][:, 0:1]
        if s["has_mask"]:
            m = refs[1][:, 0:1]
            a, b = x & m, d & m
        else:
            a, b = x.astype(jnp.dtype(s["ct"])), d
        return x, ul, (_CMP[s["op"]](a, b) & live).astype(jnp.int8)
    if op == "substring_match":
        m = s["m"]
        ends = _substring_ends_vals(x, refs[0], m, idx)
        flags = (ends > 0) & live
        if s["where"] == "start":
            flags = jnp.roll(flags, -(m - 1), axis=-1) & (idx <= n - m)
        return x, ul, flags.astype(jnp.int8)
    if op == "template_match":
        m = s["m"]
        sad = _sad_vals(x.astype(jnp.float32), refs[0], m)
        if s["mask_tail"]:
            sad = jnp.where(idx + m <= ul, sad, jnp.inf)
        return x, ul, sad
    if op == "stencil":
        base = x if s["wrap"] else jnp.where(live, x, jnp.zeros((), x.dtype))
        return x, ul, _stencil_vals(base.astype(jnp.float32), idx,
                                    s["taps"], s["wrap"], n)
    raise NotImplementedError(f"fused instruction {op!r}")


@functools.partial(jax.jit,
                   static_argnames=("instrs", "block_r", "interpret"))
def fused_stream(x: jax.Array, used_len: jax.Array, instrs, operands, *,
                 block_r: int = 1, interpret: bool | None = None):
    """Execute a fused instruction group in ONE kernel launch.

    ``x``: (R, N) device rows; ``used_len``: (R,) §4.2 length registers.
    ``instrs``: static tuple of ``(op, statics, n_operands)`` descriptors
    in stream order (``n_operands`` is emitted by the one lowering in
    ``repro.cpm.program.executors``, so the ref routing below cannot drift
    from it); ``operands``: the matching dynamic operand arrays, each
    ``(R, k)`` per-row or ``(1, k)`` broadcast.

    ``block_r`` rows load into VMEM per grid step (the autotuned knob —
    the executor picks it from the tuning cache); rows pad up to a
    multiple and the pad rows are sliced off on return, so any ``block_r``
    is bit-identical to ``block_r=1``.  The row block and its length
    register stay resident across every instruction — the Pallas
    realization of the paper's "broadcast the stream, execute in memory"
    (§3–§4).  Returns ``(rows, used_lens, producer_outputs)``.
    """
    r, n = x.shape
    counts = [nops for _, _, nops in instrs]
    assert len(operands) == sum(counts), (len(operands), counts)
    prod_dts = [FUSED_PRODUCERS[op] for op, _, _ in instrs
                if op in FUSED_PRODUCERS]

    br = max(1, min(int(block_r), r))
    pad = (-r) % br
    ul2 = used_len.reshape(r, 1)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        ul2 = jnp.pad(ul2, ((0, pad), (0, 0)))
        operands = tuple(
            jnp.pad(a, ((0, pad), (0, 0))) if a.shape[0] == r else a
            for a in operands)
    rp = r + pad

    def kernel(*refs):
        x_ref, ul_ref = refs[0], refs[1]
        pos = 2
        op_refs = []
        for c in counts:
            op_refs.append(refs[pos:pos + c])
            pos += c
        o_x, o_ul = refs[pos], refs[pos + 1]
        prod_refs = refs[pos + 2:]

        xv = x_ref[...]
        ul = ul_ref[...]                           # (br, 1) length column
        idx = jax.lax.broadcasted_iota(jnp.int32, (br, n), 1)
        pi = 0
        for (op, statics, _), orefs in zip(instrs, op_refs):
            xv, ul, out = _fused_apply(op, statics, xv, ul, orefs, idx, n)
            if out is not None:
                prod_refs[pi][...] = out
                pi += 1
        o_x[...] = xv
        o_ul[...] = jnp.broadcast_to(jnp.asarray(ul, jnp.int32), (br, 1))

    def _spec(rows, k):
        if rows == 1 and rp != 1:
            return pl.BlockSpec((1, k), lambda i: (0, 0))
        return pl.BlockSpec((br, k), lambda i: (i, 0))

    in_specs = [pl.BlockSpec((br, n), lambda i: (i, 0)),
                pl.BlockSpec((br, 1), lambda i: (i, 0))]
    in_specs += [_spec(*a.shape) for a in operands]
    out_specs = ([pl.BlockSpec((br, n), lambda i: (i, 0)),
                  pl.BlockSpec((br, 1), lambda i: (i, 0))]
                 + [pl.BlockSpec((br, n), lambda i: (i, 0))
                    for _ in prod_dts])
    out_shape = ([jax.ShapeDtypeStruct((rp, n), x.dtype),
                  jax.ShapeDtypeStruct((rp, 1), jnp.int32)]
                 + [jax.ShapeDtypeStruct((rp, n), dt) for dt in prod_dts])
    out = pl.pallas_call(
        kernel,
        grid=(rp // br,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(x, ul2, *operands)
    return out[0][:r], out[1][:r, 0], [o[:r] for o in out[2:]]
