"""Pallas TPU kernels for the paper's in-memory algorithms.

Chip-scale CPM: the VMEM block is the PE array (VREG lanes = PEs), the
kernel body is the broadcast instruction stream (Rule 5), intra-block shifts
are neighbor reads (Rule 7).

Kernels:
  * ``oddeven_sort``    — §7.7 local-exchange sort, N compare-exchange cycles
                          entirely in VMEM (used by MoE routing).
  * ``section_sum``     — §7.4 two-phase reduction: concurrent per-section
                          sums (phase 1, one grid step per section batch)
                          accumulated across the grid (phase 2).
  * ``template_match``  — §7.6 sliding SAD, ~M shift-accumulate cycles.
  * ``substring_match`` — §5 streaming needle match with neighbor carry.
  * ``stencil``         — §7.3 tap algebra, ~M shift-multiply-accumulate.

All take ``interpret=`` so the CPU container executes the kernel bodies for
validation; on TPU pass interpret=False.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# §7.7 odd-even transposition sort (row-wise)
# ---------------------------------------------------------------------------

def _oddeven_kernel(x_ref, o_ref, *, n: int, steps: int):
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

    def body(i, x):
        is_left = (idx % 2) == (i % 2)
        partner = jnp.clip(jnp.where(is_left, idx + 1, idx - 1), 0, n - 1)
        px = jnp.take_along_axis(x, partner, axis=1)
        out = jnp.where(is_left, jnp.minimum(x, px), jnp.maximum(x, px))
        solo = (partner == idx) | (is_left & (idx == n - 1))
        return jnp.where(solo, x, out)

    o_ref[...] = jax.lax.fori_loop(0, steps, body, x_ref[...])


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def oddeven_sort(x: jax.Array, steps: int | None = None, *,
                 interpret: bool = True) -> jax.Array:
    """Row-wise ascending sort of (R, N): N odd-even cycles in VMEM."""
    r, n = x.shape
    steps = n if steps is None else steps
    return pl.pallas_call(
        functools.partial(_oddeven_kernel, n=n, steps=steps),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), x.dtype),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# §7.4 two-phase sectioned sum
# ---------------------------------------------------------------------------

def _section_sum_kernel(x_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # phase 1: concurrent in-section reduction of this VMEM block
    acc_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32), axis=-1,
                            keepdims=True)

    # phase 2: the running accumulator marches across sections (grid order)
    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("section", "interpret"))
def section_sum(x: jax.Array, section: int = 1024, *,
                interpret: bool = True) -> jax.Array:
    """Two-phase global sum of a 1-D array; section = VMEM block size."""
    n = x.shape[-1]
    pad = (-n) % section
    if pad:
        x = jnp.pad(x, (0, pad))
    xs = x.reshape(1, -1)
    nsec = xs.shape[-1] // section
    out = pl.pallas_call(
        _section_sum_kernel,
        grid=(nsec,),
        in_specs=[pl.BlockSpec((1, section), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xs)
    return out[0, 0].astype(jnp.promote_types(x.dtype, jnp.float32))


# ---------------------------------------------------------------------------
# §7.6 template match (row-wise sliding SAD)
# ---------------------------------------------------------------------------

def _template_kernel(x_ref, t_ref, o_ref, *, m: int):
    x = x_ref[...].astype(jnp.float32)

    def body(j, acc):
        shifted = jnp.roll(x, -j, axis=-1)
        return acc + jnp.abs(shifted - t_ref[0, j].astype(jnp.float32))

    o_ref[...] = jax.lax.fori_loop(0, m, body, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("interpret",))
def template_match(data: jax.Array, template: jax.Array, *,
                   interpret: bool = True) -> jax.Array:
    """(R, N) x (M,) -> (R, N) SAD at every start position (wrapping tail)."""
    r, n = data.shape
    m = template.shape[-1]
    return pl.pallas_call(
        functools.partial(_template_kernel, m=m),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(data, template.reshape(1, -1))


# ---------------------------------------------------------------------------
# §5 substring match (row-wise, match-end semantics)
# ---------------------------------------------------------------------------

def _substring_kernel(x_ref, nee_ref, o_ref, *, m: int, n: int):
    x = x_ref[...]
    first = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1) == 0

    def body(i, state):
        hit = (x == nee_ref[0, i]).astype(jnp.int32)
        shifted = jnp.where(first, 0, jnp.roll(state, 1, axis=-1))
        return jnp.where(i == 0, hit, hit * shifted)

    init = jnp.zeros((1, n), jnp.int32)
    o_ref[...] = jax.lax.fori_loop(0, m, body, init).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def substring_match(hay: jax.Array, needle: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """(R, N) int rows x (M,) needle -> (R, N) int8 match-end flags."""
    r, n = hay.shape
    m = needle.shape[-1]
    return pl.pallas_call(
        functools.partial(_substring_kernel, m=m, n=n),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.int8),
        interpret=interpret,
    )(hay, needle.reshape(1, -1))


# ---------------------------------------------------------------------------
# §7.3 stencil (row-wise tap accumulation)
# ---------------------------------------------------------------------------

def _stencil_kernel(x_ref, o_ref, *, taps: tuple[float, ...]):
    x = x_ref[...].astype(jnp.float32)
    c = len(taps) // 2
    acc = jnp.zeros_like(x)
    for k, w in enumerate(taps):        # unrolled ~M shift-mul-add cycles
        if w == 0:
            continue
        acc = acc + w * jnp.roll(x, k - c, axis=-1)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("taps", "interpret"))
def stencil(x: jax.Array, taps: tuple[float, ...], *,
            interpret: bool = True) -> jax.Array:
    """(R, N) rows filtered by an odd-length tap vector (wrapping ends)."""
    r, n = x.shape
    return pl.pallas_call(
        functools.partial(_stencil_kernel, taps=taps),
        grid=(r,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(x)
