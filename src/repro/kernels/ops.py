"""jit'd public wrappers for every kernel, with backend dispatch.

``impl`` selects: "pallas" (TPU lowering, interpret=False), "interpret"
(Pallas body executed on CPU — the validation path in this container), or
"ref" (pure-jnp oracle, also the dry-run lowering so the roofline reflects
the tiled dataflow rather than interpret-mode callbacks).
"""

from __future__ import annotations

import jax

from . import cpm_kernels, flash_attention as fa, ref

DEFAULT_IMPL = "ref"          # CPU container default; TPU deployments: "pallas"


def _mode(impl):
    return DEFAULT_IMPL if impl is None else impl


def attention(q, k, v, *, causal=True, window=None, impl=None, **kw):
    m = _mode(impl)
    if m == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       **{k_: v_ for k_, v_ in kw.items()
                                          if k_ == "block_k"})
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=(m == "interpret"), **kw)


def decode_attention(q, k, v, cache_len=None, *, window=None, impl=None):
    # decode is a single-row gather-heavy op; the ref dataflow is already
    # the TPU-efficient form (no score materialization beyond (H, S)).
    return ref.decode_attention_ref(q, k, v, cache_len, window=window)


def sort(x, *, impl=None):
    m = _mode(impl)
    if m == "ref":
        return ref.oddeven_sort_ref(x)
    return cpm_kernels.oddeven_sort(x, interpret=(m == "interpret"))


def section_sum(x, *, section=1024, impl=None):
    m = _mode(impl)
    if m == "ref":
        return ref.section_sum_ref(x)
    return cpm_kernels.section_sum(x, section, interpret=(m == "interpret"))


def template_match(data, template, *, impl=None):
    m = _mode(impl)
    if m == "ref":
        return jax.vmap(lambda d: ref.template_match_ref(d, template))(data)
    return cpm_kernels.template_match(data, template,
                                      interpret=(m == "interpret"))


def substring_match(hay, needle, *, impl=None):
    m = _mode(impl)
    if m == "ref":
        out = jax.vmap(lambda h: ref.substring_match_ref(h, needle))(hay)
        return out
    return cpm_kernels.substring_match(hay, needle,
                                       interpret=(m == "interpret"))


def stencil(x, taps, *, impl=None):
    m = _mode(impl)
    if m == "ref":
        return jax.vmap(lambda r: ref.stencil_ref(r, list(taps)))(x)
    return cpm_kernels.stencil(x, tuple(taps), interpret=(m == "interpret"))
