"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention.py — blocked online-softmax attention (BlockSpec VMEM
tiling, GQA via kv index maps); cpm_kernels.py — the paper's in-memory
algorithms at chip scale (odd-even sort, two-phase section sum, template
match, substring match, stencil).  ops.py dispatches between the TPU
lowering, interpret-mode validation, and the pure-jnp oracles in ref.py.
"""

from . import cpm_kernels, flash_attention, ops, ref

__all__ = ["cpm_kernels", "flash_attention", "ops", "ref"]
