"""Pure-jnp oracles for every Pallas kernel.

These are also the implementations the models use on CPU (and in the
dry-run): ``flash_attention_ref`` is the memory-efficient chunked
online-softmax attention (lax.scan over kv chunks — same dataflow the TPU
kernel tiles into VMEM), so compiled FLOP/byte counts in the roofline match
the kernel schedule rather than a naive O(S²)-materialized softmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_naive(q, k, v, *, causal=True, window=None):
    """O(S²)-materialized softmax attention — oracle for small shapes.

    q: (B, H, Sq, D); k, v: (B, KVH, Skv, D).
    """
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    group = h // kvh
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (d ** -0.5)
    rows = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode-style)
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, block_k=512):
    """Chunked online-softmax attention (the kernel's dataflow in pure jnp).

    Memory: O(Sq · block_k) scores instead of O(Sq · Skv).  Differentiable;
    used for 32k prefill in the dry-run.

    GQA is handled by broadcasting kv up to the full head count *before* the
    einsums: the head axis then shards cleanly over the "model" mesh axis
    under GSPMD (kv heads rarely divide the TP degree).  The broadcast is a
    zero-copy view until the einsum consumes it.
    """
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    group = h // kvh
    block_k = min(block_k, skv)
    assert skv % block_k == 0
    nk = skv // block_k
    scale = d ** -0.5
    # streams stay in the input dtype (bf16 in production): every tensor
    # that crosses a sharding boundary is narrow; f32 appears only in the
    # block-local softmax statistics and the output accumulator — the same
    # precision contract as the Pallas kernel's VMEM accumulation.
    ct = q.dtype
    qf = q * jnp.asarray(scale, ct)
    kf = _repeat_kv(k, group).reshape(b, h, nk, block_k, d)
    vf = _repeat_kv(v, group).reshape(b, h, nk, block_k, d)
    rows = jnp.arange(sq)[:, None] + (skv - sq)

    def step(carry, ik):
        m, l, acc = carry
        kb = jax.lax.dynamic_index_in_dim(kf, ik, axis=2, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vf, ik, axis=2, keepdims=False)
        s = jnp.einsum("bhqd,bhcd->bhqc", qf, kb,
                       preferred_element_type=jnp.float32)
        cols = ik * block_k + jnp.arange(block_k)[None, :]
        mask = jnp.ones((sq, block_k), dtype=bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqc,bhcd->bhqd", p.astype(ct), vb,
                                       preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def _repeat_kv(x, group: int):
    if group == 1:
        return x
    b, kvh, s, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, kvh, group, s, d)
                            ).reshape(b, kvh * group, s, d)


def decode_attention_ref(q, k, v, cache_len=None, *, window=None):
    """Single-step decode attention: q (B, H, 1, D) against a (B, KVH, S, D)
    cache; positions >= cache_len are masked.  Linear in cache size.

    Grouped (no kv repeat — the cache is the dominant HBM tenant at decode;
    the slot axis shards over "model" instead of heads).  Cache may be
    stored quantized (e.g. float8_e4m3fn): it is widened to the compute
    dtype blockwise by the einsum, accumulating in f32.
    """
    b, h, _, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    group = h // kvh
    ct = jnp.float32 if q.dtype == jnp.float32 else jnp.bfloat16
    qf = (q[:, :, 0].reshape(b, kvh, group, d) * (d ** -0.5)).astype(ct)
    kk = k.astype(ct)
    vv = v.astype(ct)
    s = jnp.einsum("bkgd,bksd->bkgs", qf, kk,
                   preferred_element_type=jnp.float32)
    if cache_len is not None:
        pos = jnp.arange(skv)[None, None, None, :]
        live = pos < cache_len if jnp.ndim(cache_len) == 0 else \
            pos < cache_len[:, None, None, None]
        if window is not None:
            lo = (cache_len if jnp.ndim(cache_len) == 0
                  else cache_len[:, None, None, None])
            live &= pos >= lo - window
        s = jnp.where(live, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(ct), vv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# CPM kernels
# ---------------------------------------------------------------------------

def oddeven_sort_ref(x):
    """Row-wise ascending sort (oracle = jnp.sort)."""
    return jnp.sort(x, axis=-1)


def section_sum_ref(x, section=None):
    from repro.cpm.reference.computable import section_sum
    return section_sum(x, section)


def template_match_ref(data, template):
    from repro.cpm.reference.computable import template_match_1d
    return template_match_1d(data, template)


def substring_match_ref(hay, needle):
    from repro.cpm.reference.searchable import substring_match
    return substring_match(hay, needle)


def stencil_ref(x, taps):
    from repro.cpm.reference.computable import stencil_1d
    return stencil_1d(x, taps)
