"""repro.serve.gateway — the async traffic front door.

::

    Gateway (api)  ── submit/stream/cancel, per-request GenConfig + SLO
        │
        ▼ tick
    EngineLoop (loop) ── preempt -> pool.step -> collect
        │                    │
        │                    ├─ admission.plan: same-length buckets ->
        │                    │     ONE prefill launch per bucket;
        │                    │     parked restores, no prefill
        │                    └─ SessionPool pages (repro.cpm.pool)
        ▼
    Preemptor (preempt) ── SlotAllocator.victim() LRU -> host parking

The gateway makes the PR-5 pool's leftovers load-bearing: batched
admission amortizes prefill launches over arrival batches, and LRU
preemption (pages parked host-side, restored token-identically) lets
bursts beyond ``slots`` trade incumbent latency for burst TTFT instead
of queueing FIFO.
"""

from . import admission, api, loop, preempt
from .api import Gateway, Request
from .loop import EngineLoop
from .preempt import PreemptConfig, Preemptor

__all__ = [
    "admission", "api", "loop", "preempt",
    "Gateway", "Request", "EngineLoop", "PreemptConfig", "Preemptor",
]
