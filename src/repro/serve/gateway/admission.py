"""Batched admission planning: arrivals -> prefill buckets.

Admission is the pool's only per-session-cost path — every fresh session
pays a prefill launch.  Under bursty traffic that cost is the difference
between an O(arrivals) and an O(arrival-batches) front door (MASIM's
point about keeping the banks saturated from the host loop,
arXiv:2412.02218).  The planner groups one step's FIFO admission window:

  * **fresh** sessions bucket by prompt length — each bucket prefills as
    ONE stacked launch and scatters with ONE program;
  * **parked** sessions (preempted earlier, sub-pages saved host-side)
    form restore groups — no prefill at all, just a batched page re-seat.
    Groups bucket by *saved page count*: the restore program stacks the
    whole group's page images, so only sessions with the same number of
    live sub-pages can share one launch (under the degenerate whole-row
    layout every parked session saves one page, so this reduces to the
    old single restore group).

Pure host-side planning over Session objects; the pool executes the plan
(``SessionPool._admit_bucket`` / ``_restore_group``).  With
``batching=False`` every group has exactly one member — the strict
one-at-a-time FIFO baseline the ``serve_gateway`` benchmark compares
against.
"""

from __future__ import annotations

import dataclasses

from repro.cpm.pool.sessions import PARKED, Session


@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """One step's admission work, grouped for batched execution."""
    buckets: tuple[tuple[Session, ...], ...]   # fresh, same prompt_len each
    restores: tuple[tuple[Session, ...], ...]  # parked, no prefill needed

    @property
    def sessions(self) -> int:
        return (sum(len(b) for b in self.buckets)
                + sum(len(g) for g in self.restores))

    @property
    def launches(self) -> int:
        """Prefill launches this plan pays (restores pay none)."""
        return len(self.buckets)


def plan(sessions: list[Session], batching: bool = True) -> AdmissionPlan:
    """Group an admission window (FIFO order preserved inside every
    group).  Every planned session is admitted in the same ``step``, so
    inter-group order carries no fairness weight."""
    fresh_by_len: dict[int, list[Session]] = {}
    parked_by_pages: dict[int, list[Session]] = {}
    parked: list[Session] = []
    for s in sessions:
        if s.phase == PARKED:
            parked.append(s)
            n_pages = s.parked.n_pages if s.parked is not None else 0
            parked_by_pages.setdefault(n_pages, []).append(s)
        else:
            fresh_by_len.setdefault(s.prompt_len, []).append(s)
    if batching:
        buckets = tuple(tuple(b) for b in fresh_by_len.values())
        restores = tuple(tuple(g) for g in parked_by_pages.values())
    else:                                   # strict arrival order, one each
        buckets = tuple((s,) for s in sessions if s.phase != PARKED)
        restores = tuple((s,) for s in parked)
    return AdmissionPlan(buckets=buckets, restores=restores)
