"""The continuous engine step loop.

One ``tick`` is the gateway's heartbeat over the PR-5 pool:

  1. **preempt** — the policy (``Preemptor``) parks LRU incumbents if a
     fresh burst is queued beyond the free pages;
  2. **step** — ``SessionPool.step()``: batched admission (restores +
     prompt-length buckets), one compiled decode chunk across every live
     page, retirement;
  3. **collect** — finished Sessions (not just tokens: the gateway's SLO
     accounting wants ``first_admit_step``/``parks`` history) move into
     the delivery buffer.

Each heartbeat returns a :class:`TickReport` — the structured schema of
what the tick *did* (per-tick deltas) next to where the pool *is* (the
snapshot), replacing the loose stats dict the loop used to hand back.
Dict-style access still works (``report["waiting"]``), falling through
to the full pool-stats snapshot for legacy keys, so existing callers are
unchanged.

The loop is deliberately synchronous and deterministic — virtual time is
the pool's ``decode_steps`` — so benchmarks and identity tests drive it
tick by tick; the asyncio front door (``gateway.api``) wraps it
cooperatively.  Every tick records a ``gateway.tick`` span (wall +
virtual clock) through :mod:`repro.obs.tracing`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.obs import tracing as obs_tracing


@dataclasses.dataclass(frozen=True)
class TickReport:
    """What one heartbeat did, and where the pool stands after it.

    Schema (all counts are sessions unless noted):

    ==============  =========================================================
    field           meaning
    ==============  =========================================================
    tick            0-based index of this heartbeat
    step            pool virtual decode-step clock AFTER the tick
    admitted        fresh sessions seated this tick (stacked prefill)
    restored        parked sessions re-seated this tick (no prefill)
    preempted       sessions parked this tick (policy + page stalls)
    finished        sessions retired into the delivery buffer this tick
    emitted         tokens emitted this tick (prefill + decode), all rows
    chunk_wall_s    wall seconds dispatching this tick's compiled decode
                    chunk (0.0 when no chunk ran; dispatch only — the loop
                    never forces a device sync)
    wall_s          wall seconds of the whole tick (preempt+step+collect)
    active          sessions decoding after the tick
    waiting         fresh sessions still queued after the tick
    parked          preempted sessions queued after the tick
    pages_free      free sub-pages across all banks after the tick
    stats           the full :meth:`SessionPool.stats` snapshot (dict)
    ==============  =========================================================

    ``report[key]`` reads any field by name and falls through to
    ``stats`` for every other pool-stats key (``report["preemptions"]``),
    which keeps pre-TickReport callers working verbatim.
    """

    tick: int
    step: int
    admitted: int
    restored: int
    preempted: int
    finished: int
    emitted: int
    chunk_wall_s: float
    wall_s: float
    active: int
    waiting: int
    parked: int
    pages_free: int
    stats: dict = dataclasses.field(default_factory=dict, repr=False)

    def __getitem__(self, key: str):
        if key != "stats" and key in self.__dataclass_fields__:
            return getattr(self, key)
        return self.stats[key]

    def get(self, key: str, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


class EngineLoop:
    def __init__(self, pool, preemptor=None):
        self.pool = pool
        self.preemptor = preemptor
        self.ticks = 0
        self._finished: dict[int, Any] = {}   # sid -> Session, undelivered

    def tick(self) -> TickReport:
        """One heartbeat: preempt -> step -> collect.  Returns the
        :class:`TickReport` (deltas + snapshot) for this tick."""
        pool = self.pool
        before = {k: getattr(pool, k)
                  for k in ("admits", "restores", "preemptions",
                            "total_emitted")}
        done_before = len(self._finished)
        t0 = time.perf_counter()
        with obs_tracing.span("gateway.tick", cat="gateway",
                              vclock=pool._vclock,
                              args={"tick": self.ticks}):
            if self.preemptor is not None:
                self.preemptor.maybe_preempt()
            stats = pool.step()
            self._finished.update(
                pool.table.collect_finished_sessions())
        report = TickReport(
            tick=self.ticks,
            step=pool.decode_steps,
            admitted=pool.admits - before["admits"],
            restored=pool.restores - before["restores"],
            preempted=pool.preemptions - before["preemptions"],
            finished=len(self._finished) - done_before,
            emitted=pool.total_emitted - before["total_emitted"],
            chunk_wall_s=pool.last_chunk_s,
            wall_s=time.perf_counter() - t0,
            active=stats["active"],
            waiting=stats["waiting"],
            parked=stats["parked"],
            pages_free=stats["pages_free"],
            stats=stats,
        )
        self.ticks += 1
        return report

    def pending(self) -> bool:
        """True while any submitted session still needs ticks."""
        return not self.pool.table.all_done()

    def take_finished(self) -> dict[int, Any]:
        """Finished Sessions since the last take (delivery is
        exactly-once; the buffer empties)."""
        done, self._finished = self._finished, {}
        return done

    def run_until_idle(self, max_ticks: int = 100_000) -> dict[int, Any]:
        """Drive ticks until every session is done (tests/benchmarks);
        returns every finished Session collected along the way."""
        out: dict[int, Any] = {}
        for _ in range(max_ticks):
            if not self.pending():
                break
            self.tick()
            out.update(self.take_finished())
        else:
            raise RuntimeError(f"no convergence in {max_ticks} ticks")
        out.update(self.take_finished())
        return out
