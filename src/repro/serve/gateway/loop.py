"""The continuous engine step loop.

One ``tick`` is the gateway's heartbeat over the PR-5 pool:

  1. **preempt** — the policy (``Preemptor``) parks LRU incumbents if a
     fresh burst is queued beyond the free pages;
  2. **step** — ``SessionPool.step()``: batched admission (restores +
     prompt-length buckets), one compiled decode chunk across every live
     page, retirement;
  3. **collect** — finished Sessions (not just tokens: the gateway's SLO
     accounting wants ``first_admit_step``/``parks`` history) move into
     the delivery buffer.

The loop is deliberately synchronous and deterministic — virtual time is
the pool's ``decode_steps`` — so benchmarks and identity tests drive it
tick by tick; the asyncio front door (``gateway.api``) wraps it
cooperatively.
"""

from __future__ import annotations

from typing import Any


class EngineLoop:
    def __init__(self, pool, preemptor=None):
        self.pool = pool
        self.preemptor = preemptor
        self.ticks = 0
        self._finished: dict[int, Any] = {}   # sid -> Session, undelivered

    def tick(self) -> dict:
        """One heartbeat: preempt -> step -> collect.  Returns the pool's
        stats snapshot."""
        if self.preemptor is not None:
            self.preemptor.maybe_preempt()
        stats = self.pool.step()
        self._finished.update(self.pool.table.collect_finished_sessions())
        self.ticks += 1
        return stats

    def pending(self) -> bool:
        """True while any submitted session still needs ticks."""
        return not self.pool.table.all_done()

    def take_finished(self) -> dict[int, Any]:
        """Finished Sessions since the last take (delivery is
        exactly-once; the buffer empties)."""
        done, self._finished = self._finished, {}
        return done

    def run_until_idle(self, max_ticks: int = 100_000) -> dict[int, Any]:
        """Drive ticks until every session is done (tests/benchmarks);
        returns every finished Session collected along the way."""
        out: dict[int, Any] = {}
        for _ in range(max_ticks):
            if not self.pending():
                break
            self.tick()
            out.update(self.take_finished())
        else:
            raise RuntimeError(f"no convergence in {max_ticks} ticks")
        out.update(self.take_finished())
        return out
