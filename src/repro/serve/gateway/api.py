"""The async front door: submit / stream / cancel over the engine loop.

:class:`Gateway` owns one :class:`~repro.serve.session_pool.SessionPool`
plus the preemption policy and exposes two faces over the same
deterministic core:

  * a **sync** face (``submit``/``tick``/``result``/``cancel``) that
    benchmarks and tests drive tick-by-tick in the pool's virtual time
    (``decode_steps``);
  * an **asyncio** face (``asubmit``/``stream``/``aresult``/``serve``)
    for a live process: ``serve()`` runs the continuous tick loop
    cooperatively on the event loop, parking on an event when idle, and
    ``stream()`` yields each request's new tokens as the bank commits
    them.  A tick's compute (synchronous jax) runs in a worker thread
    via ``asyncio.to_thread``, so the event loop stays responsive —
    ``asubmit``/``stream`` consumers are never blocked behind a decode
    chunk.  Delivery (queue/event signalling) still happens on the event
    loop after the thread returns: asyncio primitives are not
    thread-safe, and the pool itself is single-writer — only the serve
    loop's one in-flight thread ever calls ``pool.step``.

Per-request knobs ride on :class:`Request`: a GenConfig override
(sampling params realized per pool row), a token budget, and an optional
``deadline_steps`` SLO — attainment is graded in virtual decode-step
time, so results are deterministic and machine-independent.
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Any, AsyncIterator

import numpy as np

from repro.obs import metrics as obs_metrics

from ..engine import GenConfig
from .loop import EngineLoop, TickReport
from .preempt import PreemptConfig, Preemptor

# registry-backed gateway accounting (one label gw="<id>" per instance);
# the legacy attributes below are series_property views over these
_GW_IDS = itertools.count()
_GW_FAMILIES = {
    "slo_met_count": obs_metrics.counter(
        "repro_gateway_slo_met_total",
        "finished requests inside their deadline", ("gw",)),
    "slo_missed_count": obs_metrics.counter(
        "repro_gateway_slo_missed_total",
        "finished requests past their deadline", ("gw",)),
    "requests_total": obs_metrics.counter(
        "repro_gateway_requests_total", "requests submitted", ("gw",)),
}


@dataclasses.dataclass
class Request:
    """One request's lifecycle record (all times in decode steps)."""
    rid: int
    prompt: np.ndarray
    gen: GenConfig
    budget: int
    deadline_steps: int | None
    arrival_step: int
    sid: int = -1
    tokens: np.ndarray | None = None   # prompt + generated, set when done
    first_admit_step: int = -1         # prefill token time (TTFT anchor)
    finish_step: int = -1
    parks: int = 0                     # times preempted
    cancelled: bool = False
    _sent: int = 0                     # stream cursor into tokens
    _stream: Any = None                # asyncio.Queue while streaming
    _done_ev: Any = None               # asyncio.Event for aresult waiters

    @property
    def done(self) -> bool:
        return self.tokens is not None

    @property
    def latency_steps(self) -> int:
        return self.finish_step - self.arrival_step

    @property
    def ttft_steps(self) -> int:
        """Steps from arrival to the first generated token (admission
        emits it via prefill)."""
        return self.first_admit_step - self.arrival_step

    @property
    def slo_met(self) -> bool | None:
        if self.deadline_steps is None or not self.done:
            return None
        return self.latency_steps <= self.deadline_steps


class Gateway:
    """Traffic front door over one Engine: batched admission, LRU
    preemption, per-request sampling params/deadlines, streaming."""

    slo_met_count = obs_metrics.series_property("slo_met_count")
    slo_missed_count = obs_metrics.series_property("slo_missed_count")

    def __init__(self, engine, slots: int = 8, n_banks: int = 1,
                 chunk: int = 1, gen: GenConfig | None = None,
                 admit_batching: bool = True,
                 preempt: bool | PreemptConfig = True,
                 bank_backend: str = "reference",
                 bank_interpret: bool | None = None, rng=None,
                 page_size: int | None = None,
                 pages_per_bank: int | None = None,
                 slo_monitor=None):
        self.gen = gen if gen is not None else GenConfig()
        self.pool = engine.session_pool(
            slots=slots, n_banks=n_banks, gen=self.gen, chunk=chunk,
            bank_backend=bank_backend, bank_interpret=bank_interpret,
            rng=rng, admit_batching=admit_batching, page_size=page_size,
            pages_per_bank=pages_per_bank)
        if preempt:
            cfg = preempt if isinstance(preempt, PreemptConfig) else None
            self.preemptor: Preemptor | None = Preemptor(self.pool, cfg)
        else:
            self.preemptor = None
        self.loop = EngineLoop(self.pool, self.preemptor)
        self._requests: dict[int, Request] = {}
        self._by_sid: dict[int, Request] = {}
        self._streaming: set[int] = set()
        self._next_rid = 0
        label = str(next(_GW_IDS))
        self._obs_series = {k: fam.labels(gw=label)
                            for k, fam in _GW_FAMILIES.items()}
        # optional obs.slo.SloMonitor: every deadline grade feeds its
        # burn-rate windows (host-side deque append, per the trace-safety
        # rule); on a multi-window burn it fires its flight recorder
        self.slo_monitor = slo_monitor
        self.last_report: TickReport | None = None
        self.http = None               # HttpFrontend while serve(http_port=)
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._tick_lock = asyncio.Lock()   # serve()'s single-writer gate
        self._stopping = False

    @property
    def now(self) -> int:
        """Virtual time: the pool's decode-step counter."""
        return self.pool.decode_steps

    # -- sync core -----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int | None = None,
               gen: GenConfig | None = None,
               deadline_steps: int | None = None) -> int:
        """Queue a request; returns its rid.  Validation (empty prompt,
        non-positive budget, overlong request) raises here, before the
        request exists."""
        sid = self.pool.submit(prompt, max_new_tokens, gen=gen)
        sess = self.pool.table.get(sid)
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt),
                      gen=gen if gen is not None else self.gen,
                      budget=sess.budget, deadline_steps=deadline_steps,
                      arrival_step=self.now, sid=sid)
        self._next_rid += 1
        self._obs_series["requests_total"].inc()
        self._requests[req.rid] = req
        self._by_sid[sid] = req
        if self._wake is not None:
            self._wake.set()
        return req.rid

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def tick(self) -> TickReport:
        """One heartbeat (preempt -> step -> collect) plus delivery:
        finished requests get their tokens/SLO grade, attached streams
        get their new tokens.  Returns the structured
        :class:`~repro.serve.gateway.loop.TickReport` (per-tick deltas +
        pool snapshot; dict-style access falls through to the snapshot
        for legacy keys)."""
        report = self.loop.tick()
        self.last_report = report
        self._publish()
        return report

    def result(self, rid: int) -> np.ndarray:
        """Drive ticks until ``rid`` finishes; returns prompt + generated."""
        req = self._requests[rid]
        while not req.done:
            self.tick()
        return req.tokens

    def cancel(self, rid: int) -> np.ndarray:
        """Abort a request in any phase; returns prompt + whatever it
        generated.  Graded against its deadline like a normal finish."""
        req = self._requests[rid]
        if req.done:
            return req.tokens
        toks = self.pool.cancel(req.sid)
        self.loop._finished.update(
            self.pool.table.collect_finished_sessions())
        sess = self.loop._finished.pop(req.sid, None)
        req.cancelled = True
        if sess is not None:
            req.first_admit_step = sess.first_admit_step
            req.parks = sess.parks
        self._finish(req, np.asarray(toks))
        return req.tokens

    def collect_delivered(self) -> list[Request]:
        """Pop every done Request (records stay with the caller; gateway
        memory stays bounded under a continuous stream)."""
        done = [r for r in self._requests.values() if r.done]
        for r in done:
            del self._requests[r.rid]
        return done

    def stats(self) -> dict:
        st = self.pool.stats()
        st.update({
            "ticks": self.loop.ticks,
            "requests": self._next_rid,
            "completed": sum(1 for r in self._requests.values() if r.done),
            "slo_met": self.slo_met_count,
            "slo_missed": self.slo_missed_count,
            "preempt_denied": (self.preemptor.denied
                               if self.preemptor else 0),
        })
        return st

    def _finish(self, req: Request, tokens: np.ndarray) -> None:
        req.tokens = tokens
        req.finish_step = self.now
        self._by_sid.pop(req.sid, None)
        if req.slo_met is True:
            self.slo_met_count += 1
        elif req.slo_met is False:
            self.slo_missed_count += 1
        if self.slo_monitor is not None and req.slo_met is not None:
            self.slo_monitor.record(req.slo_met, self.now)
        if req._done_ev is not None:
            req._done_ev.set()
        self._push_stream(req, final=True)

    def _publish(self) -> None:
        for sid, sess in self.loop.take_finished().items():
            req = self._by_sid.get(sid)
            if req is None:
                continue                   # cancelled out-of-band
            req.first_admit_step = sess.first_admit_step
            req.parks = sess.parks
            self._finish(req, np.asarray(sess.tokens))
        for rid in list(self._streaming):
            req = self._requests.get(rid)
            if req is None or req.done:
                continue
            self._push_stream(req, final=False)

    def _push_stream(self, req: Request, final: bool) -> None:
        if req._stream is None:
            return
        toks = req.tokens if final else self.pool.peek_tokens(req.sid)
        if len(toks) > req._sent:
            req._stream.put_nowait(np.asarray(toks[req._sent:]))
            req._sent = len(toks)
        if final:
            req._stream.put_nowait(None)
            self._streaming.discard(req.rid)

    # -- asyncio face --------------------------------------------------------
    def _ensure_wake(self) -> asyncio.Event:
        if self._wake is None:
            self._wake = asyncio.Event()
        return self._wake

    async def asubmit(self, prompt, max_new_tokens: int | None = None,
                      gen: GenConfig | None = None,
                      deadline_steps: int | None = None) -> int:
        rid = self.submit(prompt, max_new_tokens, gen=gen,
                          deadline_steps=deadline_steps)
        self._ensure_wake().set()
        return rid

    async def aresult(self, rid: int) -> np.ndarray:
        """Await a request's completion (serve() must be running)."""
        req = self._requests[rid]
        if req.done:
            return req.tokens
        if req._done_ev is None:
            req._done_ev = asyncio.Event()
        await req._done_ev.wait()
        return req.tokens

    async def acancel(self, rid: int) -> np.ndarray:
        """Cancel from the event loop while ``serve()`` is running.  The
        pool is single-writer: a bare ``cancel`` racing the tick thread
        could free a slot the in-flight ``pool.step`` then writes back as
        live.  This face takes the serve loop's tick lock, so the cancel
        lands strictly between heartbeats (the HTTP frontend uses it for
        client disconnects)."""
        async with self._tick_lock:
            return self.cancel(rid)

    async def stream(self, rid: int) -> AsyncIterator[np.ndarray]:
        """Async iterator of ``rid``'s NEW tokens (beyond the prompt) as
        the banks commit them; ends at finish or cancel."""
        req = self._requests[rid]
        req._sent = len(req.prompt)
        if req.done:
            if len(req.tokens) > req._sent:
                yield np.asarray(req.tokens[req._sent:])
            return
        req._stream = asyncio.Queue()
        self._streaming.add(rid)
        while True:
            chunk = await req._stream.get()
            if chunk is None:
                return
            yield chunk

    async def serve(self, idle_wait: float = 0.05,
                    http_port: int | None = None,
                    http_host: str = "127.0.0.1", **http_kw) -> None:
        """The continuous loop: tick while work is pending, park on the
        wake event (set by asubmit) when idle.

        ``http_port`` mounts the wire front for the duration of the
        loop: an :class:`~repro.serve.http.HttpFrontend` (SSE token
        streaming over ``POST /v1/generate``, ``GET /metrics`` scrapes,
        live stats, streaming trace export) bound to
        ``http_host:http_port`` (port 0 picks a free port — read it back
        from ``gateway.http.port``).  Extra keyword args pass through to
        the frontend (ring capacity, keep-alive period, detokenizer).

        The heartbeat's compute half (``EngineLoop.tick`` — preempt,
        step, collect; synchronous jax) runs in a worker thread so the
        event loop keeps servicing ``asubmit``/``stream`` during a
        decode chunk.  The delivery half (``_publish`` — queue puts,
        event sets) runs back on the event loop: asyncio primitives are
        not thread-safe.  Cross-thread safety of the pool state is the
        serve loop's single-flight discipline — exactly one tick thread
        exists at a time, and ``submit`` only appends to the host-side
        FIFO table, which the tick reads at well-defined points."""
        wake = self._ensure_wake()
        self.http = None
        if http_port is not None:
            from ..http import HttpFrontend
            self.http = HttpFrontend(self, host=http_host, port=http_port,
                                     **http_kw)
            await self.http.start()
        try:
            while not self._stopping:
                if self.loop.pending():
                    async with self._tick_lock:
                        self.last_report = await asyncio.to_thread(
                            self.loop.tick)
                        self._publish()
                else:
                    wake.clear()
                    try:
                        await asyncio.wait_for(wake.wait(),
                                               timeout=idle_wait)
                    except asyncio.TimeoutError:
                        pass
        finally:
            if self.http is not None:
                await self.http.stop()

    async def start(self, **serve_kw) -> None:
        """Run :meth:`serve` as a background task; kwargs pass through
        (``start(http_port=0)`` mounts the wire front)."""
        if self._task is None:
            self._stopping = False
            self._task = asyncio.ensure_future(self.serve(**serve_kw))

    async def stop(self) -> None:
        self._stopping = True
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
