"""LRU preemption policy over one SessionPool.

When a burst arrives beyond ``slots``, the choice is FIFO queueing (fresh
requests wait out the longest incumbent — p99 TTFT explodes) or
preemption: park the least-recently-admitted incumbent's pages host-side
(``SessionPool.park``) and give its slot to the burst.  The victim comes
from the already-proven CPM machinery — ``SlotAllocator.victim()`` runs
§7.5 ``global_limit("min")`` over allocation ticks on the metadata
device — so "who is LRU" is itself a concurrent-memory query, not a host
scan.

This module is the *policy*; the mechanism (page save/restore, FIFO
re-queue, token-identical continuation) is the pool's.  Guards keep the
policy from thrashing:

  * only **fresh** WAITING arrivals justify eviction — a parked session
    never evicts anyone (it re-queues at the FIFO tail instead);
  * a victim must have been resident ``min_resident`` decode steps since
    its last (re-)admission;
  * sessions within ``min_remaining`` tokens of finishing are cheaper to
    let drain than to park;
  * ``max_parks`` bounds how often one session can be preempted
    (starvation guard).

The loop is conservative: the allocator names exactly one LRU candidate
per query, and if that candidate is protected the whole round stops —
better to queue a burst briefly than to churn pages.
"""

from __future__ import annotations

import dataclasses

from repro.cpm.pool.sessions import WAITING
from repro.obs import metrics as obs_metrics

# policy-level accounting, labeled by the pool the policy governs (the
# mechanism's parks are the pool's own repro_pool_preemptions_total)
_PREEMPT_FAMILIES = {
    "preempted": obs_metrics.counter(
        "repro_preempt_evicted_total",
        "LRU victims parked by the policy", ("pool",)),
    "denied": obs_metrics.counter(
        "repro_preempt_denied_total",
        "preemption rounds stopped by a protected LRU candidate",
        ("pool",)),
}


@dataclasses.dataclass(frozen=True)
class PreemptConfig:
    min_resident: int = 2      # decode steps between (re-)admission and eviction
    min_remaining: int = 2     # don't park sessions about to finish
    max_parks: int = 3         # per-session preemption cap


class Preemptor:
    preempted = obs_metrics.series_property("preempted")
    denied = obs_metrics.series_property("denied")

    def __init__(self, pool, cfg: PreemptConfig | None = None):
        self.pool = pool
        self.cfg = cfg if cfg is not None else PreemptConfig()
        self._obs_series = {
            k: fam.labels(pool=pool._pool_label)
            for k, fam in _PREEMPT_FAMILIES.items()}

    def _protected(self, sess) -> bool:
        cfg, pool = self.cfg, self.pool
        return (pool.decode_steps - sess.admit_step < cfg.min_resident
                or sess.budget - sess.emitted <= cfg.min_remaining
                or sess.parks >= cfg.max_parks)

    def maybe_preempt(self) -> int:
        """Park LRU victims until every fresh arrival could be seated (or
        the LRU candidate is protected).  Returns how many were parked.

        Seating is two-resource under the paged layout: a fresh session
        needs a slot AND its admission page grant.  Pressure on *either*
        resource justifies eviction — a parked victim frees both its slot
        and its whole page list at once (under the degenerate whole-row
        layout pages and slots are one-to-one, so the two deficits
        coincide and this reduces to the old slot-only policy)."""
        pool = self.pool
        window = pool.table.peek_waiting(pool.table.waiting_count())
        fresh = [s for s in window if s.phase == WAITING]
        want = len(fresh) - pool._free_hint
        want_pages = (sum(pool._grant0(s.prompt_len) for s in fresh)
                      - pool.alloc.page_free_count())
        parked = 0
        while want > 0 or want_pages > 0:
            sess = pool.victim_session()
            if sess is None or sess.finished:
                break                       # nothing evictable right now
            if self._protected(sess):
                self.denied += 1
                break                       # LRU is protected: stop, don't churn
            held = len(pool.alloc.pages(sess.slot))
            pool.park(sess.sid)
            self.preempted += 1
            parked += 1
            want -= 1
            want_pages -= held
        return parked
