"""Serving hot paths expressed as recorded CPM programs.

The speculative-decode commit is the engine's per-round device-side
sequence (paper ops in parentheses):

  1. ``verify_draft``  — the §5 searchable carry chain over draft vs
     teacher-forced predictions (``repro.cpm.reference.searchable``),
     producing each row's accepted prefix length;
  2. ``truncate``      — the §4.2 range delete that rolls the KV cache
     back to the accepted length (``kv_cache.truncate``, lengths only);
  3. ``insert``        — the §4.2 range insert that commits the accepted
     tokens into the output buffer at each row's live end.

Steps 2–3 on the *token buffer* are expressed here as a two-instruction
``CPMProgram`` (``insert`` then ``truncate`` — append the whole round's
predictions, then roll back to the accepted prefix; the §4.2 length
register makes the rollback free).  The stream is scheduled
*cost-aware*: on the pallas backend the launch/byte model
(``repro.cpm.program.costmodel``) decides per commit whether the pair
runs as ONE ``fused_stream`` mega-kernel launch (launch-bound regimes —
compiled TPU) or as per-op dispatch (interpreter/CPU hosts, where eager
ops jit-fuse for free and the mega-kernel only adds overhead).  Either
way the instructions — and the committed tokens — are identical.

Token-identity with the legacy scatter commit is enforced by
``tests/test_engine_equiv.py`` (engine vs step-by-step oracle) and
``tests/test_program.py`` (fused vs eager reference, bit-identical).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.cpm import CPMArray, CPMProgram
from repro.cpm.program import schedule


def record_commit_program(buf, used, preds, emit_n,
                          backend: str = "reference",
                          interpret: bool | None = None):
    """Build (but do not run) the commit stream for one verify round.

    Returns ``(device, plan)``: the token-buffer device and the scheduled
    fusion plan of ``insert(used, preds) -> truncate(used + emit_n)``.
    The stream is built explicitly — it is exactly what
    ``with cpm.record(): dev.insert(used, preds).truncate(used + emit_n)``
    would trace, but the hot path must not pay the tracer's eager
    reference execution on every non-jit call.

    Scheduling is cost-aware (the device geometry is known here), so the
    plan's group is ``fused`` or ``eager`` per the backend's calibrated
    launch/byte model rather than hardcoded — see the module docstring.
    """
    used = jnp.asarray(used, jnp.int32)
    dev = CPMArray(jnp.asarray(buf), used, backend=backend,
                   interpret=interpret)
    prog = CPMProgram() \
        .append("insert", pos=used, values=preds) \
        .append("truncate", new_len=used + emit_n)
    return dev, schedule(prog, device=dev, backend=backend,
                         interpret=interpret)


def commit_tokens(buf, used, preds, emit_n, backend: str = "reference",
                  interpret: bool | None = None):
    """Commit one speculative round into the token buffer.

    ``buf``: (B, cap) output tokens; ``used``: (B,) live lengths (prompt +
    already-emitted); ``preds``: (B, draft_len) this round's teacher-forced
    predictions; ``emit_n``: (B,) budget-clipped accepted counts.

    Appends all ``draft_len`` predictions at each row's live end and rolls
    the length register back to ``used + emit_n`` — physically identical
    (within the returned live region) to the legacy per-element scatter,
    but expressed as a broadcast instruction stream: one fused kernel
    launch on the pallas backend.  Returns ``(new_buf, new_used)``.
    """
    dev, plan = record_commit_program(buf, used, preds, emit_n,
                                      backend=backend, interpret=interpret)
    out, _ = plan.run(dev, backend=backend, interpret=interpret)
    return out.data, out.used_len
