"""The wire protocol: a stdlib-asyncio HTTP/1.1 front over the Gateway.

:class:`HttpFrontend` mounts four read paths and one write path on the
PR-7 gateway, all pure stdlib (``asyncio.start_server`` + hand-rolled
HTTP/1.1 — the container adds no web framework and none is needed):

  * ``POST /v1/generate`` — JSON request carrying the prompt, an
    optional per-request :class:`~repro.serve.engine.GenConfig` override
    and ``deadline_steps`` SLO.  ``"stream": true`` (default) answers
    with an SSE stream riding :meth:`Gateway.stream` — each committed
    token chunk is one ``tokens`` event, so the wire emits exactly the
    chunks the in-process async face emits (byte-identity is asserted in
    tests and the ``serve_http`` bench).  Keep-alive comment frames go
    out while a long prefill holds the first token back, and a client
    that disconnects mid-stream cancels its request through the
    gateway's ``cancel`` path (the pool reclaims the pages).
  * ``GET /metrics`` — the process-global registry in Prometheus text
    exposition, straight from :func:`repro.obs.metrics.prometheus_text`.
  * ``GET /healthz`` / ``GET /v1/stats`` — liveness and the structured
    view: last :class:`TickReport`, pool stats, SLO monitor state,
    registry snapshot.
  * ``GET /debug/trace`` — the live trace ring streamed as chunked
    Chrome/Perfetto ``trace_event`` JSON via
    :func:`repro.obs.export.iter_trace_chunks` — O(ring) memory no
    matter how long the server has been up.

The frontend performs **no device work**: every handler reads host
mirrors (registry cells, ring snapshots, request records), so attaching
it cannot change what compiles — the PR-9 overhead invariants (identical
program cache keys, 3 pallas launches per bank per chunk, zero device
syncs from recording) are re-asserted with the HTTP plane attached in
``tests/test_http.py``.

The module also ships the minimal client half (``request``,
``stream_body``, :class:`SSEDecoder`) used by the tests, the
``serve_http`` benchmark and the example — incremental SSE parsing that
is correct under arbitrary byte-chunk splits, including mid-UTF-8.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Any, AsyncIterator, Callable

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.obs.export import iter_trace_chunks
from repro.obs.live import TraceRing
from repro.obs.slo import FlightRecorder, SloMonitor

from .engine import GenConfig

_MAX_HEADER_LINE = 65536
_MAX_HEADERS = 100
_MAX_BODY = 8 << 20
_GEN_FIELDS = {f.name for f in dataclasses.fields(GenConfig)}

_HTTP_FAMILIES = {
    "http_requests": obs_metrics.counter(
        "repro_http_requests_total", "HTTP requests served",
        ("route", "code")),
    "http_sse_events": obs_metrics.counter(
        "repro_http_sse_events_total", "SSE frames written", ("kind",)),
    "http_disconnects": obs_metrics.counter(
        "repro_http_disconnects_total",
        "client disconnects mid-stream (request cancelled)", ()),
}

_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 413: "Payload Too Large",
           500: "Internal Server Error"}


def sse_event(event: str, data: Any) -> bytes:
    """One SSE frame: ``event:`` + JSON ``data:`` lines, blank-line
    terminated.  ``data`` is JSON-encoded (so embedded newlines are
    escaped and one ``data:`` line always suffices)."""
    payload = json.dumps(data, separators=(",", ":"), ensure_ascii=False)
    return f"event: {event}\ndata: {payload}\n\n".encode("utf-8")


class SSEDecoder:
    """Incremental SSE parser: feed raw body bytes in ANY split —
    mid-line, mid-frame, mid-UTF-8-sequence — and collect complete
    ``(event, data)`` frames.  Bytes are buffered and only decoded once
    a full frame (blank-line terminated) is present, so a multi-byte
    character split across transport chunks can never mis-decode."""

    def __init__(self):
        self._buf = b""
        self.comments: list[str] = []

    def feed(self, data: bytes) -> list[tuple[str, str]]:
        self._buf += data
        frames: list[tuple[str, str]] = []
        while True:
            # frame terminator: blank line (tolerate \r\n line endings)
            for sep in (b"\n\n", b"\r\n\r\n"):
                cut = self._buf.find(sep)
                if cut >= 0:
                    raw, self._buf = (self._buf[:cut],
                                      self._buf[cut + len(sep):])
                    break
            else:
                return frames
            event, datas = "message", []
            for line in raw.decode("utf-8").splitlines():
                if line.startswith(":"):
                    self.comments.append(line[1:].strip())
                elif line.startswith("event:"):
                    event = line[len("event:"):].strip()
                elif line.startswith("data:"):
                    datas.append(line[len("data:"):].lstrip())
            if datas:
                frames.append((event, "\n".join(datas)))


class HttpFrontend:
    """The HTTP/SSE wire front over one :class:`Gateway`.

    The frontend only serves; the gateway's tick loop must be running
    (``await gateway.start()``, or use ``gateway.serve(http_port=...)``
    which mounts and unmounts the frontend around the loop).  ``port=0``
    binds an ephemeral port, read back from :attr:`port` after
    :meth:`start`.
    """

    def __init__(self, gateway, host: str = "127.0.0.1", port: int = 0, *,
                 detokenize: Callable[[list[int]], str] | None = None,
                 ring_capacity: int = 4096,
                 tracer_limit: int | None = 65536,
                 keepalive_s: float = 5.0,
                 slo_monitor: SloMonitor | None = None,
                 recorder_dir: str = "artifacts/flightrec",
                 flight_last_n: int = 256):
        self.gateway = gateway
        self.host = host
        self.port = port
        self.detokenize = detokenize
        self.keepalive_s = keepalive_s
        self.ring = TraceRing(ring_capacity)
        self._tracer_limit = tracer_limit
        self._saved_limit: int | None = None
        self._server: asyncio.AbstractServer | None = None
        # wire the SLO plane: grades flow from Gateway._finish into the
        # monitor; a multi-window burn dumps the flight recorder (last-N
        # ring spans + registry + allocator page table, atomic write)
        if slo_monitor is not None:
            self.slo_monitor = slo_monitor
        elif getattr(gateway, "slo_monitor", None) is not None:
            self.slo_monitor = gateway.slo_monitor
        else:
            self.recorder = FlightRecorder(recorder_dir, ring=self.ring,
                                           pool=gateway.pool,
                                           last_n=flight_last_n)
            self.slo_monitor = SloMonitor(recorder=self.recorder)
        gateway.slo_monitor = self.slo_monitor

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> "HttpFrontend":
        self.ring.attach(obs_tracing.TRACER)
        if self._tracer_limit is not None:
            # bound the process-global tracer too: a week of traffic must
            # not grow host memory (the ring serves the live exports)
            self._saved_limit = obs_tracing.TRACER.max_events
            obs_tracing.TRACER.set_limit(self._tracer_limit)
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        self.ring.detach()
        if self._tracer_limit is not None:
            obs_tracing.TRACER.set_limit(self._saved_limit)

    # -- request plumbing ---------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        route = "?"
        try:
            try:
                method, path, headers = await self._read_head(reader)
            except (ValueError, asyncio.IncompleteReadError,
                    ConnectionResetError):
                return
            route = path.split("?", 1)[0]
            body = b""
            length = int(headers.get("content-length", "0") or "0")
            if length > _MAX_BODY:
                await self._respond(writer, 413, {"error": "body too large"})
                return
            if length:
                body = await reader.readexactly(length)
            await self._route(method, route, body, reader, writer)
        except (ConnectionResetError, BrokenPipeError):
            pass
        except Exception as e:                      # noqa: BLE001
            try:
                await self._respond(writer, 500, {"error": repr(e)},
                                    route=route)
            except (ConnectionResetError, BrokenPipeError):
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    async def _read_head(self, reader):
        line = await reader.readline()
        if not line:
            raise ValueError("empty request")
        if len(line) > _MAX_HEADER_LINE:
            raise ValueError("request line too long")
        parts = line.decode("latin-1").split()
        if len(parts) < 3:
            raise ValueError(f"malformed request line {line!r}")
        method, path = parts[0].upper(), parts[1]
        headers: dict[str, str] = {}
        for _ in range(_MAX_HEADERS):
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            if len(line) > _MAX_HEADER_LINE:
                raise ValueError("header line too long")
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        else:
            raise ValueError("too many headers")
        return method, path, headers

    async def _route(self, method, route, body, reader, writer):
        gw = self.gateway
        if route == "/healthz" and method == "GET":
            await self._respond(writer, 200, {
                "ok": True, "step": gw.now, "ticks": gw.loop.ticks,
                "pending": gw.loop.pending()}, route=route)
        elif route == "/metrics" and method == "GET":
            await self._respond(
                writer, 200, obs_metrics.prometheus_text().encode("utf-8"),
                content_type="text/plain; version=0.0.4; charset=utf-8",
                route=route)
        elif route == "/v1/stats" and method == "GET":
            rep = gw.last_report
            await self._respond(writer, 200, {
                "tick": rep.asdict() if rep is not None else None,
                "stats": gw.stats(),
                "slo": (self.slo_monitor.state()
                        if self.slo_monitor is not None else None),
                "ring": self.ring.stats(),
                "metrics": obs_metrics.snapshot()}, route=route)
        elif route == "/debug/trace" and method == "GET":
            await self._stream_trace(writer, route)
        elif route == "/v1/generate":
            if method != "POST":
                await self._respond(writer, 405, {"error": "POST only"},
                                    route=route)
            else:
                await self._generate(body, reader, writer, route)
        elif route in ("/healthz", "/metrics", "/v1/stats", "/debug/trace"):
            await self._respond(writer, 405, {"error": "GET only"},
                                route=route)
        else:
            await self._respond(writer, 404, {"error": f"no route {route}"},
                                route=route)

    # -- responses ----------------------------------------------------------
    def _count(self, route: str, code: int) -> None:
        _HTTP_FAMILIES["http_requests"].inc(route=route, code=str(code))

    async def _respond(self, writer, code: int, body,
                       content_type: str = "application/json",
                       route: str | None = None) -> None:
        if isinstance(body, (dict, list)):
            body = json.dumps(body, indent=1, default=_jsonable).encode()
        head = (f"HTTP/1.1 {code} {_STATUS.get(code, '?')}\r\n"
                f"Content-Type: {content_type}\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n")
        writer.write(head.encode("latin-1") + body)
        await writer.drain()
        if route is not None:
            self._count(route, code)

    async def _start_chunked(self, writer, content_type: str) -> None:
        head = ("HTTP/1.1 200 OK\r\n"
                f"Content-Type: {content_type}\r\n"
                "Cache-Control: no-store\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n\r\n")
        writer.write(head.encode("latin-1"))
        await writer.drain()

    async def _chunk(self, writer, data: bytes) -> None:
        if not data:
            return
        writer.write(f"{len(data):x}\r\n".encode("latin-1") + data + b"\r\n")
        await writer.drain()

    async def _end_chunked(self, writer) -> None:
        writer.write(b"0\r\n\r\n")
        await writer.drain()

    async def _stream_trace(self, writer, route: str) -> None:
        await self._start_chunked(writer, "application/json")
        for chunk in iter_trace_chunks(self.ring):
            await self._chunk(writer, chunk.encode("utf-8"))
        await self._end_chunked(writer)
        self._count(route, 200)

    # -- /v1/generate -------------------------------------------------------
    def _parse_generate(self, body: bytes) -> dict:
        try:
            req = json.loads(body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise ValueError(f"bad JSON body: {e}")
        if not isinstance(req, dict):
            raise ValueError("body must be a JSON object")
        prompt = req.get("prompt")
        if not isinstance(prompt, list) or \
                not all(isinstance(t, int) for t in prompt):
            raise ValueError('"prompt" must be a list of token ids')
        gen_kw = req.get("gen", {})
        if not isinstance(gen_kw, dict):
            raise ValueError('"gen" must be an object')
        unknown = set(gen_kw) - _GEN_FIELDS
        if unknown:
            raise ValueError(f"unknown gen fields {sorted(unknown)}; "
                             f"have {sorted(_GEN_FIELDS)}")
        gen = (dataclasses.replace(self.gateway.gen, **gen_kw)
               if gen_kw else None)
        return {
            "prompt": np.asarray(prompt, np.int32),
            "max_new_tokens": req.get("max_new_tokens"),
            "gen": gen,
            "deadline_steps": req.get("deadline_steps"),
            "stream": bool(req.get("stream", True)),
        }

    def _token_payload(self, rid: int, tokens: np.ndarray) -> dict:
        toks = [int(t) for t in np.asarray(tokens)]
        payload = {"rid": rid, "tokens": toks}
        if self.detokenize is not None:
            payload["text"] = self.detokenize(toks)
        return payload

    def _done_payload(self, rid: int) -> dict:
        req = self.gateway.request(rid)
        return {"rid": rid, "n_tokens": int(len(req.tokens)),
                "ttft_steps": req.ttft_steps,
                "latency_steps": req.latency_steps,
                "slo_met": req.slo_met, "parks": req.parks,
                "cancelled": req.cancelled}

    async def _generate(self, body, reader, writer, route) -> None:
        try:
            spec = self._parse_generate(body)
        except ValueError as e:
            await self._respond(writer, 400, {"error": str(e)}, route=route)
            return
        try:
            rid = await self.gateway.asubmit(
                spec["prompt"], spec["max_new_tokens"], gen=spec["gen"],
                deadline_steps=spec["deadline_steps"])
        except ValueError as e:                 # pool-level validation
            await self._respond(writer, 400, {"error": str(e)}, route=route)
            return
        if not spec["stream"]:
            tokens = await self.gateway.aresult(rid)
            await self._respond(writer, 200, dict(
                self._done_payload(rid),
                **self._token_payload(rid, tokens)), route=route)
            return
        await self._sse_stream(rid, reader, writer, route)

    async def _sse_stream(self, rid, reader, writer, route) -> None:
        """The SSE body: one ``tokens`` event per committed chunk —
        chunks arrive exactly as ``Gateway.stream`` yields them, so the
        wire is byte-identical in token content to the in-process face.
        A keep-alive comment goes out every ``keepalive_s`` of silence
        (long prefills), and EOF on the request socket (client gone)
        cancels the request through the gateway."""
        gw = self.gateway
        await self._start_chunked(writer, "text/event-stream")
        agen = gw.stream(rid)
        next_t = asyncio.ensure_future(agen.__anext__())
        eof_t = asyncio.ensure_future(reader.read(1))
        disconnected = False
        try:
            await self._chunk(writer, sse_event("start", {"rid": rid}))
            _HTTP_FAMILIES["http_sse_events"].inc(kind="start")
            while True:
                done, _ = await asyncio.wait(
                    {next_t, eof_t}, timeout=self.keepalive_s,
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_t in done:              # client closed its end
                    disconnected = True
                    break
                if not done:                   # silence: long prefill
                    await self._chunk(writer, b": keep-alive\n\n")
                    _HTTP_FAMILIES["http_sse_events"].inc(kind="keepalive")
                    continue
                try:
                    tokens = next_t.result()
                except StopAsyncIteration:
                    break
                await self._chunk(writer, sse_event(
                    "tokens", self._token_payload(rid, tokens)))
                _HTTP_FAMILIES["http_sse_events"].inc(kind="tokens")
                next_t = asyncio.ensure_future(agen.__anext__())
            if not disconnected:
                await self._chunk(writer, sse_event(
                    "done", self._done_payload(rid)))
                _HTTP_FAMILIES["http_sse_events"].inc(kind="done")
                await self._end_chunked(writer)
                self._count(route, 200)
        except (ConnectionResetError, BrokenPipeError):
            disconnected = True
        finally:
            next_t.cancel()
            eof_t.cancel()
            if disconnected and not gw.request(rid).done:
                # acancel, not cancel: the serve loop's tick thread may be
                # mid-step, and a bare cancel would race its write-back
                await gw.acancel(rid)
                _HTTP_FAMILIES["http_disconnects"].inc()
                self._count(route, 499)        # nginx-style client abort


def _jsonable(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)}")


# -- minimal async client (tests / benchmarks / examples) -------------------

async def _read_response_head(reader):
    status_line = await reader.readline()
    parts = status_line.decode("latin-1").split()
    status = int(parts[1])
    headers: dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        k, _, v = line.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers


async def _iter_body(reader, headers) -> AsyncIterator[bytes]:
    if headers.get("transfer-encoding", "").lower() == "chunked":
        while True:
            size_line = await reader.readline()
            size = int(size_line.strip().split(b";")[0], 16)
            if size == 0:
                await reader.readline()        # trailing CRLF
                return
            data = await reader.readexactly(size)
            await reader.readexactly(2)        # chunk CRLF
            yield data
    elif "content-length" in headers:
        yield await reader.readexactly(int(headers["content-length"]))
    else:
        while True:
            data = await reader.read(65536)
            if not data:
                return
            yield data


def _request_bytes(method: str, path: str, host: str,
                   body: bytes | None) -> bytes:
    head = (f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
            f"Accept: */*\r\n")
    if body is not None:
        head += (f"Content-Type: application/json\r\n"
                 f"Content-Length: {len(body)}\r\n")
    return (head + "Connection: close\r\n\r\n").encode("latin-1") + \
        (body or b"")


async def request(host: str, port: int, method: str, path: str,
                  body: dict | bytes | None = None):
    """One full request/response; returns ``(status, headers, body)``
    with chunked bodies reassembled."""
    if isinstance(body, dict):
        body = json.dumps(body).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes(method, path, host, body))
        await writer.drain()
        status, headers = await _read_response_head(reader)
        chunks = [c async for c in _iter_body(reader, headers)]
        return status, headers, b"".join(chunks)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass


async def sse_events(host: str, port: int, path: str, body: dict,
                     decoder: SSEDecoder | None = None):
    """POST ``body`` and yield decoded ``(event, data_json_str)`` SSE
    frames until the server ends the stream."""
    payload = json.dumps(body).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes("POST", path, host, payload))
        await writer.drain()
        status, headers = await _read_response_head(reader)
        if status != 200:
            chunks = [c async for c in _iter_body(reader, headers)]
            raise RuntimeError(
                f"HTTP {status}: {b''.join(chunks).decode()}")
        dec = decoder if decoder is not None else SSEDecoder()
        async for raw in _iter_body(reader, headers):
            for frame in dec.feed(raw):
                yield frame
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
