"""Step-by-step reference engine — the differential-test oracle.

This is the original (pre-scan) serving path, kept verbatim in spirit: a
Python ``while`` loop with one ``lm.decode_step`` call — and one host
sync — per token, and a batch-size-1 prompt-lookup speculative round that
re-invokes ``decode_step`` once per draft token.  It is slow on purpose:
its value is that every intermediate is observable and the control flow is
trivially auditable, so ``tests/test_engine_equiv.py`` can assert the
scan-based production engine (``engine.Engine``) is token-identical to it.

Scope notes (inherited limitations, acceptable in an oracle):
  * speculative rounds support batch == 1 only and global-attention KV
    rollback only (``kv_cache.truncate``); the production engine handles
    batch > 1, recurrent-state rollback and local-window rings.
  * ``stats["accepted"]`` counts tokens of the final round even when they
    overshoot ``max_new_tokens`` and are sliced off; the production engine
    reports clipped counts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.cpm.reference import searchable
from repro.models import lm
from . import kv_cache, sampling
from .engine import GenConfig


class ReferenceEngine:
    """Single-program batched engine (static batch, step-synchronous)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 jit: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(functools.partial(lm.prefill, cfg=cfg),
                                static_argnames=("max_len",)) if jit else \
            functools.partial(lm.prefill, cfg=cfg)
        self._decode = jax.jit(functools.partial(lm.decode_step, cfg=cfg)) if jit \
            else functools.partial(lm.decode_step, cfg=cfg)

    def generate(self, batch: dict, gen: GenConfig, rng=None):
        """Returns (tokens (B, prompt+new), per-step acceptance stats)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        b, s = tokens.shape
        logits, caches = self._prefill(self.params, batch=batch,
                                       max_len=self.max_len)
        out = tokens
        pos = s
        stats = {"accepted": 0, "proposed": 0}
        nxt = self._sample(logits[:, -1], gen, rng)
        out = jnp.concatenate([out, nxt[:, None]], axis=1)

        while out.shape[1] - s < gen.max_new_tokens:
            rng, sub = jax.random.split(rng)
            if gen.ngram_spec and out.shape[1] > gen.ngram_spec + 2 and b == 1:
                out, caches, pos, acc, prop = self._spec_round(
                    out, caches, pos, gen, sub)
                stats["accepted"] += acc
                stats["proposed"] += prop
            else:
                logits, caches = self._decode(self.params, tokens_t=out[:, -1:],
                                              caches=caches,
                                              pos=jnp.asarray(pos, jnp.int32))
                pos += 1
                nxt = self._sample(logits[:, -1], gen, sub)
                out = jnp.concatenate([out, nxt[:, None]], axis=1)
        return out[:, : s + gen.max_new_tokens], stats

    def _sample(self, logits, gen: GenConfig, rng):
        return sampling.sample(logits, rng, gen.temperature, gen.top_k, gen.top_p)

    # -- prompt-lookup speculative decoding (content-searchable memory) ----

    def _spec_round(self, out, caches, pos, gen: GenConfig, rng):
        n = min(gen.ngram_len, out.shape[1] - 1)
        ctx = out[0]
        ngram = ctx[-n:]
        starts, valid = searchable.ngram_lookup(ctx[:-1], ngram,
                                                max_out=1)
        draft_len = gen.ngram_spec
        if bool(valid[0]):
            st = int(starts[0])
            draft = np.asarray(ctx[st: st + draft_len])
            draft = np.pad(draft, (0, draft_len - draft.shape[0]),
                           constant_values=0)
        else:
            draft = np.zeros((draft_len,), np.int32)     # degenerate draft
        draft = jnp.asarray(draft, jnp.int32)

        # verify: run the model over [last_token, draft[:-1]] step by step,
        # sampling greedily; acceptance = searchable carry chain.
        seq = jnp.concatenate([out[0, -1:], draft[:-1]])
        preds = []
        c = caches
        p = pos
        for t in range(draft_len):
            logits, c = self._decode(self.params, tokens_t=seq[t][None, None],
                                     caches=c, pos=jnp.asarray(p, jnp.int32))
            preds.append(sampling.greedy(logits[:, -1])[0])
            p += 1
        preds = jnp.stack(preds)                          # model's tokens
        n_acc = int(searchable.verify_draft(draft, preds))
        n_emit = min(n_acc + 1, draft_len)                # +1 model token
        emitted = jnp.where(jnp.arange(draft_len) < n_acc, draft, preds)[:n_emit]
        out = jnp.concatenate([out, emitted[None]], axis=1)
        # rollback cache entries past the accepted prefix (movable delete)
        new_pos = pos + n_emit
        c = kv_cache.truncate(c, jnp.asarray(new_pos, jnp.int32))
        return out, c, new_pos, n_acc, draft_len
