"""Continuous batching over paged CPM banks.

The static engine runs one batch to completion: a single slow request pins
every row's VMEM/HBM for the whole generation.  The session pool replaces
that with the paper's facility view of memory (§4.2): a fixed set of
*pages* — KV-cache rows and token-buffer bank rows — that sessions check
in and out of mid-flight:

  * ``submit``  — queue a prompt + token budget (FIFO), optionally with
    per-request sampling params (a GenConfig override);
  * ``step``    — admit waiting sessions into free pages with **batched
    admission** (same-length prompts bucket into ONE stacked prefill
    launch + ONE scatter program, so admission cost scales with arrival
    batches, not arrivals; parked sessions restore in one group, no
    prefill), decode a ``chunk`` of tokens for every page in ONE
    compiled program (an inner scan with per-row positions) that also
    commits each bank's tokens through the MASIM packer's pre-collapsed
    ``insert -> truncate`` stream (``MultiBankScheduler.compiled_commit``
    — one fused launch per bank on pallas), then retire finished
    sessions and reclaim their pages;
  * ``park``    — preempt an ACTIVE session: its KV/token pages are
    saved to a host-side :class:`PageState` parking buffer, the slot is
    freed, and the session re-queues FIFO for a later restore that
    continues the token stream exactly where it was cut (the LRU
    *policy* lives in ``repro.serve.gateway.preempt``; this is the
    mechanism);
  * ``cancel``  — abort a session in any phase, returning what ran;
  * ``drain``   — step until every submitted session is done.

Bookkeeping is CPM all the way down: free-page lookups run on the
allocator's metadata device (§6 ``compare`` + Rule-6 drain, ``compact``
for the packed used-page list), token commits are §4.2
``insert``/``truncate`` instruction streams, and pages move through the
scalar-prefetch gather/scatter kernels on pallas banks.  The host keeps
only mirrors (live flags, budgets) — a steady-state step is one compiled
call, no device round-trips.

Correctness contract: under greedy decoding the pool is **token-identical**
to generating each session alone with ``Engine.generate`` — decode math is
row-independent, admission replays the same per-session prefill, and each
session sees exactly the same (token, position, cache) sequence it would
see solo, at any ``chunk`` size (a session finishing mid-chunk keeps
decoding into slack like the static engine's overshoot rows; the commit
clamps to its budget so overshoot tokens never surface).  The identity
survives preemption: decode math is row-independent and ``(KV rows, pos,
cur, token row)`` fully determine a session's future, so a parked page
image restored into *any* free slot replays the same stream —
``tests/test_session_pool.py`` and ``tests/test_gateway.py`` assert both
differentially.  Sampled decoding is supported (per-request sampling
params via :func:`repro.serve.sampling.sample_rows`, per-step rng) but
makes no cross-engine identity claim — the rng schedule differs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.cpm.pool import CPMBank, MultiBankScheduler, SessionTable, SlotAllocator
from repro.cpm.pool.sessions import ACTIVE, DONE, PARKED
from repro.models import lm
from . import kv_cache, sampling


@dataclasses.dataclass
class PageState:
    """Host-side parking image of one preempted session: everything the
    pooled decode needs to continue token-identically from any free slot
    — its KV rows (blocks leaves sliced at batch axis 1, tail leaves at
    axis 0; the per-row ``len`` leaves ride along in the same trees), the
    scan position, the current token, and its token-bank row."""
    caches: Any                        # {"blocks": [...], "tail": [...]} np
    pos: int
    cur: int
    row: np.ndarray                    # (max_len,) token page
    row_len: int


class SessionPool:
    """Paged continuous-batching state for one :class:`~repro.serve.Engine`.

    ``slots`` pages are split across ``n_banks`` equal banks (the model
    batch is the concatenation of all banks' rows).  ``gen`` fixes the
    pool-wide sampling parameters; per-session budgets come from
    ``submit``.  ``chunk`` tokens decode per ``step`` inside one compiled
    program — larger chunks amortize dispatch, at the cost of coarser
    admission/retirement granularity.  ``bank_backend``/``bank_interpret``
    route the token banks ("pallas" turns each chunk's bank commit into
    one fused mega-kernel launch and page moves into scalar-prefetch DMA
    kernels).  ``admit_batching=False`` degrades admission to strict
    one-at-a-time FIFO (buckets of one) — the baseline policy the
    ``serve_gateway`` benchmark compares against.
    """

    def __init__(self, engine, slots: int = 8, n_banks: int = 1, gen=None,
                 chunk: int = 1, bank_backend: str = "reference",
                 bank_interpret: bool | None = None, rng=None,
                 admit_batching: bool = True):
        from .engine import GenConfig

        if engine.cfg.enc_dec:
            raise NotImplementedError(
                "session pool supports decoder-only models (cross-attention "
                "pages are encoder-owned)")
        if slots <= 0 or n_banks <= 0 or slots % n_banks:
            raise ValueError(f"slots ({slots}) must be a positive multiple "
                             f"of n_banks ({n_banks})")
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.engine = engine
        self.gen = gen if gen is not None else GenConfig()
        self.slots = slots
        self.n_banks = n_banks
        self.rows_per_bank = slots // n_banks
        self.chunk = chunk
        self.max_len = engine.max_len
        self._bank_backend = bank_backend
        self._bank_interpret = bank_interpret

        self.alloc = SlotAllocator(slots)
        self.banks = [CPMBank(self.rows_per_bank, self.max_len,
                              backend=bank_backend,
                              interpret=bank_interpret)
                      for _ in range(n_banks)]
        self.sched = MultiBankScheduler(self.banks)
        self.table = SessionTable()

        caches = lm.init_caches(engine.cfg, slots, self.max_len)
        self.caches = kv_cache.broadcast_lens(caches, slots)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros((slots,), bool)
        self._free_hint = slots            # host mirror of the free count
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.admit_batching = admit_batching

        # host mirrors of each slot's sampling params (per-request
        # GenConfig overrides realized as (slots,) vectors for the chunk)
        self._temp = np.full((slots,), self.gen.temperature, np.float32)
        self._topk = np.full((slots,), self.gen.top_k, np.int32)
        self._topp = np.full((slots,), self.gen.top_p, np.float32)

        self.decode_steps = 0
        self.total_emitted = 0
        self._decode_emitted = 0           # excludes prefill tokens
        self.prefill_launches = 0
        self.admit_batches = 0
        self.preemptions = 0
        self.restores = 0
        self.cancels = 0

    # -- public API ---------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int | None = None,
               gen=None) -> int:
        """Queue one session; returns its id.

        ``gen`` optionally overrides the pool GenConfig's *sampling*
        params (temperature/top_k/top_p) for this session — the serving
        gateway's per-request knobs.  The budget comes from
        ``max_new_tokens``, falling back to the per-request then the pool
        GenConfig.  Degenerate requests are rejected here, before they
        can occupy a page: empty prompts and non-positive budgets raise
        ``ValueError``.
        """
        tokens = jnp.asarray(tokens, jnp.int32).reshape(-1)
        s = int(tokens.shape[0])
        if s < 1:
            raise ValueError(
                "empty prompt: a session needs at least one prompt token")
        g = self.gen if gen is None else gen
        if gen is not None and getattr(gen, "ngram_spec", 0):
            raise ValueError(
                "pooled serving is non-speculative: per-request "
                "ngram_spec is not supported")
        budget = g.max_new_tokens if max_new_tokens is None else max_new_tokens
        if budget <= 0:
            raise ValueError(
                f"max_new_tokens must be positive, got {budget}: a "
                "session must generate at least one token")
        if s + budget > self.max_len:
            raise ValueError(
                f"prompt ({s}) + budget ({budget}) exceeds max_len "
                f"({self.max_len}); pages are max_len wide")
        sess = self.table.add(tokens, s, budget)
        sess.gen = g
        return sess.sid

    def step(self) -> dict:
        """Admit -> decode ``chunk`` tokens for every live page -> retire.

        Returns a stats snapshot (see :meth:`stats`)."""
        self._admit()
        self._retire()                      # budget-1 sessions finish on admit
        if self.table.active_count():
            self._decode_chunk()
            self._retire()
        return self.stats()

    def drain(self) -> dict[int, np.ndarray]:
        """Step until every submitted session is DONE; returns
        ``{sid: (prompt + generated,) int32}`` for the sessions finished
        since the last drain (delivered sessions are evicted from the
        table — memory stays bounded under a continuous request stream)."""
        while not self.table.all_done():
            self.step()
        return self.table.collect_finished()

    def stats(self) -> dict:
        steps = self.decode_steps
        return {
            "decode_steps": steps,
            "emitted": self.total_emitted,
            # useful (budgeted) *decode* tokens per slot-step — dead pages,
            # chunk overshoot and drained-out tails all count against it
            # (prefill tokens are excluded: they cost no decode step)
            "occupancy": (self._decode_emitted / (steps * self.slots)
                          if steps else 0.0),
            "active": self.table.active_count(),
            # fresh arrivals only; parked sessions are queued but counted
            # separately (they already hold generated state)
            "waiting": (self.table.waiting_count()
                        - self.table.parked_count()),
            "parked": self.table.parked_count(),
            "bank_launches": self.sched.bank_launches,
            "streams_packed": self.sched.streams_packed,
            "prefill_launches": self.prefill_launches,
            "admit_batches": self.admit_batches,
            "preemptions": self.preemptions,
            "restores": self.restores,
            "cancels": self.cancels,
        }

    # -- admission ----------------------------------------------------------
    def _admit(self) -> None:
        """Admit up to ``free`` queued sessions this step.

        The admission *plan* (``repro.serve.gateway.admission``) splits
        the FIFO window into parked-session restore groups (no prefill)
        and same-prompt-length buckets of fresh sessions; every bucket
        pays ONE stacked prefill launch + ONE scatter program regardless
        of its size.  With ``admit_batching=False`` every group has one
        member — the strict FIFO baseline."""
        from .gateway import admission
        take = min(self._free_hint, self.table.waiting_count())
        if not take:
            return
        plan = admission.plan(self.table.peek_waiting(take),
                              batching=self.admit_batching)
        for group in plan.restores:
            self._restore_group(list(group))
        for bucket in plan.buckets:
            self._admit_bucket(list(bucket))

    def _alloc_slots(self, k: int) -> list[int]:
        slots = []
        for _ in range(k):
            slot = self.alloc.alloc()       # CPM free-page lookup
            assert slot is not None, "free-count mirror out of sync"
            slots.append(slot)
        self._free_hint -= k
        return slots

    def _note_admit(self, sess, slot: int) -> None:
        """Host mirrors for one freshly seated session."""
        sess.admit_step = self.decode_steps
        if sess.first_admit_step < 0:
            sess.first_admit_step = self.decode_steps
        self.live[slot] = True
        self._temp[slot] = sess.gen.temperature
        self._topk[slot] = sess.gen.top_k
        self._topp[slot] = sess.gen.top_p

    def _admit_bucket(self, bucket) -> None:
        """Check a same-prompt-length bucket of fresh sessions in with one
        batched prefill and one scatter program."""
        engine = self.engine
        k, s = len(bucket), bucket[0].prompt_len
        slots = self._alloc_slots(k)
        prompts = jnp.stack([sess.prompt for sess in bucket])
        logits, caches1 = engine._prefill(
            engine.params, batch={"tokens": prompts}, max_len=self.max_len)
        caches1 = kv_cache.broadcast_lens(caches1, k)
        admit = engine._program("pool_admit", self.gen, self._build_admit,
                                s, k, self.slots)
        self._rng, sub = jax.random.split(self._rng)
        rng = jax.random.fold_in(sub, bucket[0].sid)
        temp = jnp.asarray([se.gen.temperature for se in bucket], jnp.float32)
        topk = jnp.asarray([se.gen.top_k for se in bucket], jnp.int32)
        topp = jnp.asarray([se.gen.top_p for se in bucket], jnp.float32)
        self.caches, self.pos, self.cur, rows = admit(
            self.caches, caches1, jnp.asarray(slots, jnp.int32), self.pos,
            self.cur, logits, prompts, temp, topk, topp, rng)
        self.prefill_launches += 1
        self.admit_batches += 1
        per_bank: dict[int, list[int]] = {}
        for i, (sess, slot) in enumerate(zip(bucket, slots)):
            bank_id = slot // self.rows_per_bank
            self.table.activate(sess.sid, bank_id, slot)
            self._note_admit(sess, slot)
            sess.emitted = 1                # the prefill token
            self.total_emitted += 1
            per_bank.setdefault(bank_id, []).append(i)
        for bank_id, members in per_bank.items():
            locals_ = jnp.asarray(
                [slots[i] % self.rows_per_bank for i in members], jnp.int32)
            self.banks[bank_id].scatter(
                locals_, rows[jnp.asarray(members, jnp.int32)],
                jnp.asarray([s + 1] * len(members), jnp.int32))

    def _build_admit(self, s: int, k: int, slots: int):
        """Jitted batched page check-in for ``k`` prompts of length ``s``:
        sample each row's prefill token with its own sampling params,
        scatter the bucket's KV into pool rows ``idx`` (blocks batch axis
        1, tail axis 0 — whole rows replaced, nothing from the pages'
        previous tenants survives), seed pos/cur, and build the
        token-bank rows."""
        del slots                           # cache-key discriminator
        engine, width = self.engine, self.max_len

        def run(pool_caches, new_caches, idx, pos, cur, logits, prompts,
                temp, topk, topp, rng):
            first = sampling.sample_rows(logits[:, -1], rng, temp, topk,
                                         topp)

            def wr_b(p, n):
                return p.at[:, idx].set(n.astype(p.dtype))

            def wr_t(p, n):
                return p.at[idx].set(n.astype(p.dtype))

            caches = {
                "blocks": jax.tree.map(wr_b, pool_caches["blocks"],
                                       new_caches["blocks"]),
                "tail": jax.tree.map(wr_t, pool_caches["tail"],
                                     new_caches["tail"]),
            }
            pos = pos.at[idx].set(s)
            cur = cur.at[idx].set(first)
            rows = (jnp.zeros((k, width), jnp.int32)
                    .at[:, :s].set(prompts)
                    .at[jnp.arange(k), s].set(first))
            return caches, pos, cur, rows

        return jax.jit(run) if engine._jit else run

    # -- preemption (mechanism) ---------------------------------------------
    def park(self, sid: int) -> None:
        """Preempt an ACTIVE session: save its pages into a host-side
        :class:`PageState`, free its slot, and re-queue it at the FIFO
        tail for a later token-identical restore.  The *policy* — who
        gets parked, and when — lives in
        ``repro.serve.gateway.preempt``."""
        sess = self.table.get(sid)
        if sess.phase != ACTIVE:
            raise ValueError(f"session {sid} is {sess.phase}, not active")
        if sess.finished:
            raise ValueError(f"session {sid} already hit its budget; "
                             "step() will retire it")
        slot = sess.slot
        row, ln = self.banks[sess.bank].read_row(slot % self.rows_per_bank)
        assert ln == sess.prompt_len + sess.emitted, (
            ln, sess.prompt_len, sess.emitted)
        image = {
            "blocks": jax.tree.map(lambda p: p[:, slot],
                                   self.caches["blocks"]),
            "tail": jax.tree.map(lambda p: p[slot], self.caches["tail"]),
        }
        sess.parked = PageState(
            caches=jax.device_get(image), pos=int(self.pos[slot]),
            cur=int(self.cur[slot]), row=np.asarray(row), row_len=int(ln))
        sess.parks += 1
        self.preemptions += 1
        self.table.park(sid)
        self.alloc.free(slot)               # page back to the free list
        self._free_hint += 1
        self.live[slot] = False
        self.pos = self.pos.at[slot].set(0)
        self.cur = self.cur.at[slot].set(0)

    def _restore_group(self, group) -> None:
        """Re-admit parked sessions: ONE scatter program re-seats the
        whole group's saved KV/pos/cur images (no prefill — the saved
        pages already hold the history), then each token row scatters
        back into its new bank."""
        k = len(group)
        slots = self._alloc_slots(k)
        states = [sess.parked for sess in group]
        blocks = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1),
                              *[st.caches["blocks"] for st in states])
        tail = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                            *[st.caches["tail"] for st in states])
        restore = self.engine._program("pool_restore", self.gen,
                                       self._build_restore, k, self.slots)
        self.caches, self.pos, self.cur = restore(
            self.caches, blocks, tail, jnp.asarray(slots, jnp.int32),
            self.pos, self.cur,
            jnp.asarray([st.pos for st in states], jnp.int32),
            jnp.asarray([st.cur for st in states], jnp.int32))
        per_bank: dict[int, list[int]] = {}
        for i, (sess, slot) in enumerate(zip(group, slots)):
            bank_id = slot // self.rows_per_bank
            self.table.activate(sess.sid, bank_id, slot)
            self._note_admit(sess, slot)
            sess.parked = None
            self.restores += 1
            per_bank.setdefault(bank_id, []).append(i)
        for bank_id, members in per_bank.items():
            locals_ = jnp.asarray(
                [slots[i] % self.rows_per_bank for i in members], jnp.int32)
            rows = jnp.stack(
                [jnp.asarray(states[i].row, jnp.int32) for i in members])
            lens = jnp.asarray([states[i].row_len for i in members],
                               jnp.int32)
            self.banks[bank_id].scatter(locals_, rows, lens)

    def _build_restore(self, k: int, slots: int):
        """Jitted batched page re-seat for ``k`` parked sessions: write
        the saved KV images into the newly allocated rows and restore
        pos/cur — the decode stream continues exactly where preemption
        cut it."""
        del k, slots                        # cache-key discriminators
        engine = self.engine

        def run(pool_caches, blocks, tail, idx, pos, cur, spos, scur):
            def wr_b(p, n):
                return p.at[:, idx].set(n.astype(p.dtype))

            def wr_t(p, n):
                return p.at[idx].set(n.astype(p.dtype))

            caches = {
                "blocks": jax.tree.map(wr_b, pool_caches["blocks"], blocks),
                "tail": jax.tree.map(wr_t, pool_caches["tail"], tail),
            }
            return caches, pos.at[idx].set(spos), cur.at[idx].set(scur)

        return jax.jit(run) if engine._jit else run

    def victim_session(self):
        """The allocator's LRU eviction candidate (§7.5 min-over-ticks on
        the metadata device) as a Session, or None when nothing is
        evictable."""
        slot = self.alloc.victim()
        return self.table.at_slot(slot) if slot is not None else None

    # -- cancellation / inspection ------------------------------------------
    def cancel(self, sid: int) -> np.ndarray:
        """Abort a session in any phase; returns prompt + whatever it
        generated before the cancel.  The tokens stay collectible (DONE)
        until the next drain/collect."""
        sess = self.table.get(sid)
        if sess.phase == DONE:
            return np.asarray(sess.tokens)
        if sess.phase == ACTIVE:
            slot = sess.slot
            row, ln = self.banks[sess.bank].read_row(
                slot % self.rows_per_bank)
            self.table.finish(sid, np.asarray(row[:ln]))
            self.alloc.free(slot)
            self._free_hint += 1
            self.live[slot] = False
            self.pos = self.pos.at[slot].set(0)
            self.cur = self.cur.at[slot].set(0)
        elif sess.phase == PARKED:
            st = sess.parked
            self.table.finish(sid, np.asarray(st.row[:st.row_len]))
        else:                               # WAITING: nothing ran yet
            self.table.finish(sid, np.asarray(sess.prompt))
        self.cancels += 1
        return np.asarray(sess.tokens)

    def peek_tokens(self, sid: int) -> np.ndarray:
        """Host snapshot of a session's tokens so far (prompt + emitted),
        in any phase — what the gateway's streaming iterator reads."""
        sess = self.table.get(sid)
        if sess.phase == ACTIVE:
            row, _ = self.banks[sess.bank].read_row(
                sess.slot % self.rows_per_bank)
            return np.asarray(row[:sess.prompt_len + sess.emitted])
        if sess.phase == PARKED:
            return np.asarray(sess.parked.row[:sess.parked.row_len])
        if sess.phase == DONE:
            return np.asarray(sess.tokens)
        return np.asarray(sess.prompt)

    # -- decode -------------------------------------------------------------
    def _decode_chunk(self) -> None:
        """One compiled program: scan ``chunk`` decode steps over every
        page, then commit each bank's tokens via the scheduler's packed
        ``insert -> truncate`` stream — no host round-trip inside."""
        engine = self.engine
        run = engine._program("pool_chunk", self.gen, self._build_chunk,
                              self.slots, self.chunk, self.n_banks,
                              self._bank_backend, self._bank_interpret)
        self._rng, sub = jax.random.split(self._rng)
        budget_left = np.zeros((self.slots,), np.int32)
        for sess in self.table.active():
            budget_left[sess.slot] = sess.budget - sess.emitted
        datas = [b.data for b in self.banks]
        lenss = [b.lens for b in self.banks]
        self.cur, self.caches, self.pos, datas, lenss = run(
            engine.params, self.cur, self.caches, self.pos,
            jnp.asarray(self.live), jnp.asarray(budget_left),
            jnp.asarray(self._temp), jnp.asarray(self._topk),
            jnp.asarray(self._topp), datas, lenss, sub)
        for b, d, ln in zip(self.banks, datas, lenss):
            b.data, b.lens = d, ln

        active = self.table.active()
        for sess in active:                 # host-mirror accounting only
            emit = min(self.chunk, sess.budget - sess.emitted)
            sess.emitted += emit
            self.total_emitted += emit
            self._decode_emitted += emit
        self.decode_steps += self.chunk
        self.sched.bank_launches += self.n_banks    # packed commit launches
        self.sched.streams_packed += len(active)

    def _build_chunk(self, slots: int, chunk: int, n_banks: int,
                     bank_backend: str, bank_interpret):
        """Jitted pooled decode chunk: an inner scan of ``chunk``
        ``lm.decode_step`` calls with per-row positions (dead pages stay
        pinned — pos frozen, token 0 — and only write their own row),
        followed by the per-bank packed commit.  Rows whose budget ends
        mid-chunk keep decoding into slack; ``emit`` clamps what the
        commit makes visible."""
        del bank_backend, bank_interpret    # cache-key discriminators: the
        # compiled_commit closures below bake the bank routing in
        engine, cfg = self.engine, self.engine.cfg
        rpb = self.rows_per_bank
        commits = [self.sched.compiled_commit(b, chunk)
                   for b in range(n_banks)]

        def run(params, cur, caches, pos, live, budget_left, temp, topk,
                topp, datas, lenss, rng):
            def body(carry, _):
                tok, caches, pos, rng = carry
                rng, sub = jax.random.split(rng)
                logits, caches = lm.decode_step(params, cfg, tok[:, None],
                                                caches, pos)
                nxt = sampling.sample_rows(logits[:, -1], sub, temp, topk,
                                           topp)
                nxt = jnp.where(live, nxt, 0)
                pos = jnp.where(live, pos + 1, pos)
                return (nxt, caches, pos, rng), nxt

            (cur, caches, pos, _), toks = jax.lax.scan(
                body, (cur, caches, pos, rng), None, length=chunk)
            toks = jnp.moveaxis(toks, 0, 1)              # (slots, chunk)
            emit = jnp.where(live, jnp.minimum(budget_left, chunk), 0)
            new_d, new_l = [], []
            for b in range(n_banks):
                rows = slice(b * rpb, (b + 1) * rpb)
                d, ln = commits[b](datas[b], lenss[b], toks[rows],
                                   emit[rows])
                new_d.append(d)
                new_l.append(ln)
            return cur, caches, pos, new_d, new_l

        return jax.jit(run) if engine._jit else run

    # -- retirement ---------------------------------------------------------
    def _retire(self) -> None:
        for sess in list(self.table.active()):
            if not sess.finished:
                continue
            bank = self.banks[sess.bank]
            local = sess.slot % self.rows_per_bank
            row, ln = bank.read_row(local)
            assert ln == sess.prompt_len + sess.emitted, (
                ln, sess.prompt_len, sess.emitted)
            self.table.finish(sess.sid, row[:ln])
            self.alloc.free(sess.slot)      # page back to the free list
            self._free_hint += 1
            self.live[sess.slot] = False
            # pin the dead page: frozen position, token 0 — its decode
            # writes stay inside its own (soon-to-be-recycled) row
            self.pos = self.pos.at[sess.slot].set(0)
            self.cur = self.cur.at[sess.slot].set(0)
