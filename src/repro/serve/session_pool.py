"""Continuous batching over paged CPM banks.

The static engine runs one batch to completion: a single slow request pins
every row's VMEM/HBM for the whole generation.  The session pool replaces
that with the paper's facility view of memory (§4.2): a fixed set of
fixed-size **sub-pages** — KV-cache pages and token-buffer bank pages —
that sessions check in and out of mid-flight:

  * ``submit``  — queue a prompt + token budget (FIFO), optionally with
    per-request sampling params (a GenConfig override);
  * ``step``    — admit waiting sessions into free pages with **batched
    admission** (same-length prompts bucket into ONE stacked prefill
    launch + ONE scatter program, so admission cost scales with arrival
    batches, not arrivals; parked sessions restore in one group, no
    prefill), decode a ``chunk`` of tokens for every session in ONE
    compiled program (an inner scan with per-row positions) that reads
    and commits KV **through the page table**, then retire finished
    sessions and reclaim their pages;
  * ``park``    — preempt an ACTIVE session: only its LIVE sub-pages are
    saved to a host-side :class:`PageState` parking buffer, the slot and
    page list are freed, and the session re-queues FIFO for a later
    restore that continues the token stream exactly where it was cut
    (the LRU *policy* lives in ``repro.serve.gateway.preempt``; this is
    the mechanism);
  * ``cancel``  — abort a session in any phase, returning what ran;
  * ``drain``   — step until every submitted session is done.

Paged layout (the vLLM idea expressed as CPM ops): storage is
``page_size``-token sub-pages, not ``max_len`` rows.  Each session holds
an ordered *page list* (``SlotAllocator.pages``); a per-slot page table
``(slots, C)`` maps logical page ranks to sub-page ids.  Global-attn KV
leaves live as page pools (``kv_cache.paged_pool``), token rows as
``(pages_per_bank, page_size)`` banks.  The compiled chunk gathers each
session's FULL logical row through the table (bit-identical attention —
same width, same mask as the un-paged layout), scans ``chunk`` decode
steps, then scatters back only the *dirty* pages (ranks touched since
the chunk started; clean pages keep their sentinel and drop).  Sessions
are admitted with ``ceil((prompt+1)/page_size)`` pages and topped up
host-side between chunks (``_ensure_pages``) with enough slack to cover
the next chunk — a session crossing a page boundary mid-decode never
stalls the compiled step.  When a bank runs dry the youngest sessions
park (their pages free instantly), so the oldest always progresses and
a lone session can never livelock.

Bookkeeping is CPM all the way down: free-slot and free-page lookups run
on the allocator's metadata devices (§6 ``compare`` + Rule-6 drain,
§7.5 ``global_limit(min)`` for the LRU victim), token commits are §4.2
``insert``/``truncate`` instruction streams over the gathered logical
rows, and sub-pages move through the scalar-prefetch gather/scatter
kernels on pallas banks.  The host keeps only mirrors (live flags,
budgets, page lists) — a steady-state step is one compiled call, no
device round-trips.

Correctness contract: under greedy decoding the pool is **token-identical**
to generating each session alone with ``Engine.generate`` — decode math is
row-independent, admission replays the same per-session prefill, the paged
gather/scatter round-trip is a pure copy, and each session sees exactly
the same (token, position, cache) sequence it would see solo, at any
``chunk`` size (a session finishing mid-chunk keeps decoding into slack
like the static engine's overshoot rows; the commit clamps to its budget
so overshoot tokens never surface).  The identity survives preemption:
``(live sub-pages, pos, cur, token row)`` fully determine a session's
future, so a parked page image restored into *any* free slot + page list
replays the same stream — ``tests/test_session_pool.py`` and
``tests/test_gateway.py`` assert both differentially.  Sampled decoding
is supported (per-request sampling params via
:func:`repro.serve.sampling.sample_rows`, per-step rng) but makes no
cross-engine identity claim — the rng schedule differs.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.cpm.pool import CPMBank, MultiBankScheduler, SessionTable, SlotAllocator
from repro.cpm.pool.sessions import ACTIVE, DONE, PARKED
from repro.models import lm
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from . import kv_cache, sampling

# -- registry-backed accounting ---------------------------------------------
# Each pool instance is one label (pool="<id>") on these shared families;
# the pool's legacy counter attributes (``pool.prefill_launches`` etc.) are
# ``series_property`` views over its series, so ``stats()`` and the
# telemetry exports read the very same cells.  All host arithmetic —
# nothing here ever touches a device array (the PR-6 trace-safety rule).
_POOL_IDS = itertools.count()

_POOL_COUNTERS = {
    "decode_steps": ("repro_pool_decode_steps_total",
                     "virtual decode-step clock (chunks x chunk size)"),
    "total_emitted": ("repro_pool_emitted_total",
                      "tokens emitted (prefill + decode)"),
    "_decode_emitted": ("repro_pool_decode_emitted_total",
                        "budgeted decode tokens (excludes prefill)"),
    "submitted": ("repro_pool_submitted_total", "sessions submitted"),
    "admits": ("repro_pool_admits_total",
               "fresh sessions admitted (restores counted separately)"),
    "prefill_launches": ("repro_pool_prefill_launches_total",
                         "stacked prefill launches"),
    "admit_batches": ("repro_pool_admit_batches_total",
                      "same-length admission buckets executed"),
    "preemptions": ("repro_pool_preemptions_total", "sessions parked"),
    "page_stalls": ("repro_pool_page_stalls_total",
                    "parks forced by page pressure"),
    "restores": ("repro_pool_restores_total", "parked sessions restored"),
    "cancels": ("repro_pool_cancels_total", "sessions cancelled"),
}
_POOL_GAUGES = {
    "active": ("repro_pool_active", "sessions decoding this step"),
    "waiting": ("repro_pool_waiting", "fresh sessions queued"),
    "parked": ("repro_pool_parked", "preempted sessions queued"),
    "pages_free": ("repro_pool_pages_free", "free sub-pages, all banks"),
    "occupancy": ("repro_pool_occupancy",
                  "budgeted decode tokens per slot-step"),
}
_POOL_FAMILIES = (
    {k: obs_metrics.counter(name, help, ("pool",))
     for k, (name, help) in _POOL_COUNTERS.items()}
    | {k: obs_metrics.gauge(name, help, ("pool",))
       for k, (name, help) in _POOL_GAUGES.items()}
)
_CHUNK_SECONDS = obs_metrics.histogram(
    "repro_pool_chunk_seconds",
    "wall seconds per compiled decode chunk (dispatch, no forced sync)",
    ("pool",))


@dataclasses.dataclass
class PageState:
    """Host-side parking image of one preempted session: everything the
    pooled decode needs to continue token-identically from any free slot
    — its LIVE KV sub-pages flattened to a logical ``n_pages *
    page_size`` row per global-attn leaf (per-slot leaves — rings,
    recurrent states, lengths — ride along in the same trees), the scan
    position, the current token, and its token row."""
    caches: Any                        # {"blocks": [...], "tail": [...]} np
    pos: int
    cur: int
    row: np.ndarray                    # (row_len,) token content
    row_len: int
    n_pages: int                       # live sub-pages saved per leaf


class SessionPool:
    """Paged continuous-batching state for one :class:`~repro.serve.Engine`.

    ``slots`` sessions are split across ``n_banks`` equal banks (the model
    batch is the concatenation of all banks' rows).  ``page_size`` sets
    the sub-page width in tokens (default: ``max_len`` — one page per
    session, the degenerate whole-row layout); ``pages_per_bank`` sets
    each bank's sub-page pool size (default: enough for every slot's
    worst case, i.e. whole-row capacity).  A *paged* pool uses
    ``page_size < max_len`` with ``pages_per_bank`` well below the worst
    case — capacity is then bounded by tokens actually resident, not by
    ``slots * max_len``.  ``gen`` fixes the pool-wide sampling
    parameters; per-session budgets come from ``submit``.  ``chunk``
    tokens decode per ``step`` inside one compiled program — larger
    chunks amortize dispatch, at the cost of coarser
    admission/retirement granularity.  ``bank_backend``/``bank_interpret``
    route the token banks ("pallas" turns each chunk's bank commit into
    one fused mega-kernel launch and sub-page moves into scalar-prefetch
    DMA kernels).  ``admit_batching=False`` degrades admission to strict
    one-at-a-time FIFO (buckets of one) — the baseline policy the
    ``serve_gateway`` benchmark compares against.
    """

    # legacy counter attributes, now thin views over the pool's registry
    # series (``self._obs_series``) — ``pool.prefill_launches += 1`` keeps
    # working and the metrics exports see the same numbers
    decode_steps = obs_metrics.series_property("decode_steps")
    total_emitted = obs_metrics.series_property("total_emitted")
    _decode_emitted = obs_metrics.series_property("_decode_emitted")
    submitted = obs_metrics.series_property("submitted")
    admits = obs_metrics.series_property("admits")
    prefill_launches = obs_metrics.series_property("prefill_launches")
    admit_batches = obs_metrics.series_property("admit_batches")
    preemptions = obs_metrics.series_property("preemptions")
    page_stalls = obs_metrics.series_property("page_stalls")
    restores = obs_metrics.series_property("restores")
    cancels = obs_metrics.series_property("cancels")

    def __init__(self, engine, slots: int = 8, n_banks: int = 1, gen=None,
                 chunk: int = 1, bank_backend: str = "reference",
                 bank_interpret: bool | None = None, rng=None,
                 admit_batching: bool = True, page_size: int | None = None,
                 pages_per_bank: int | None = None):
        from .engine import GenConfig

        if engine.cfg.enc_dec:
            raise NotImplementedError(
                "session pool supports decoder-only models (cross-attention "
                "pages are encoder-owned)")
        if slots <= 0 or n_banks <= 0 or slots % n_banks:
            raise ValueError(f"slots ({slots}) must be a positive multiple "
                             f"of n_banks ({n_banks})")
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.engine = engine
        self.gen = gen if gen is not None else GenConfig()
        self.slots = slots
        self.n_banks = n_banks
        self.rows_per_bank = slots // n_banks
        self.chunk = chunk
        self.max_len = engine.max_len
        self._bank_backend = bank_backend
        self._bank_interpret = bank_interpret

        pg = self.max_len if page_size is None else page_size
        if not 0 < pg <= self.max_len or self.max_len % pg:
            raise ValueError(
                f"page_size ({pg}) must be a positive divisor of max_len "
                f"({self.max_len})")
        self.page_size = pg
        self.C = self.max_len // pg        # page-table width per slot
        ppb = (self.rows_per_bank * self.C if pages_per_bank is None
               else pages_per_bank)
        if ppb <= 0:
            raise ValueError(f"pages_per_bank must be positive, got {ppb}")
        self.pages_per_bank = ppb
        self.total_pages = n_banks * ppb   # doubles as the table sentinel

        self.alloc = SlotAllocator(slots, n_pages=self.total_pages)
        self.banks = [CPMBank(ppb, pg, backend=bank_backend,
                              interpret=bank_interpret)
                      for _ in range(n_banks)]
        self.sched = MultiBankScheduler(self.banks)
        self.table = SessionTable()

        caches = lm.init_caches(engine.cfg, slots, self.max_len)
        caches = kv_cache.broadcast_lens(caches, slots)
        self.caches = kv_cache.paged_pool(caches, engine.cfg,
                                          self.total_pages, pg)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur = jnp.zeros((slots,), jnp.int32)
        self.tok_lens = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros((slots,), bool)
        self._free_hint = slots            # host mirror of the free count
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.admit_batching = admit_batching

        # host mirrors of each slot's sampling params (per-request
        # GenConfig overrides realized as (slots,) vectors for the chunk)
        self._temp = np.full((slots,), self.gen.temperature, np.float32)
        self._topk = np.full((slots,), self.gen.top_k, np.int32)
        self._topp = np.full((slots,), self.gen.top_p, np.float32)

        # per-pool telemetry series: the counter attributes declared on the
        # class read/write these cells (fresh label -> fresh zeroed series)
        self._pool_label = str(next(_POOL_IDS))
        self._obs_series = {k: fam.labels(pool=self._pool_label)
                            for k, fam in _POOL_FAMILIES.items()}
        self._chunk_hist = _CHUNK_SECONDS.labels(pool=self._pool_label)
        self.last_chunk_s = 0.0            # wall time of the last chunk

    # -- paging arithmetic --------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Sub-pages needed to hold ``tokens`` of content."""
        return -(-tokens // self.page_size)

    def _bank_of(self, slot: int) -> int:
        return slot // self.rows_per_bank

    def _page_range(self, bank: int) -> tuple[int, int]:
        """Bank ``bank``'s slice of the global sub-page id space."""
        return bank * self.pages_per_bank, (bank + 1) * self.pages_per_bank

    def _grant0(self, prompt_len: int) -> int:
        """Admission grant: pages covering the prompt + its prefill token."""
        return min(self.C, self.pages_for(prompt_len + 1))

    # -- public API ---------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int | None = None,
               gen=None) -> int:
        """Queue one session; returns its id.

        ``gen`` optionally overrides the pool GenConfig's *sampling*
        params (temperature/top_k/top_p) for this session — the serving
        gateway's per-request knobs.  The budget comes from
        ``max_new_tokens``, falling back to the per-request then the pool
        GenConfig.  Degenerate requests are rejected here, before they
        can occupy a page: empty prompts, non-positive budgets, requests
        longer than a logical row, and requests whose worst-case page
        count exceeds one bank's capacity all raise ``ValueError``.
        """
        tokens = jnp.asarray(tokens, jnp.int32).reshape(-1)
        s = int(tokens.shape[0])
        if s < 1:
            raise ValueError(
                "empty prompt: a session needs at least one prompt token")
        g = self.gen if gen is None else gen
        if gen is not None and getattr(gen, "ngram_spec", 0):
            raise ValueError(
                "pooled serving is non-speculative: per-request "
                "ngram_spec is not supported")
        budget = g.max_new_tokens if max_new_tokens is None else max_new_tokens
        if budget <= 0:
            raise ValueError(
                f"max_new_tokens must be positive, got {budget}: a "
                "session must generate at least one token")
        if s + budget > self.max_len:
            raise ValueError(
                f"prompt ({s}) + budget ({budget}) exceeds max_len "
                f"({self.max_len}); pages are max_len wide")
        worst = min(self.C,
                    self.pages_for(s + budget - 1 + self.chunk))
        if worst > self.pages_per_bank:
            raise ValueError(
                f"prompt ({s}) + budget ({budget}) needs up to {worst} "
                f"sub-pages of {self.page_size} tokens, but bank capacity "
                f"is {self.pages_per_bank} pages — the session could "
                f"never be seated")
        sess = self.table.add(tokens, s, budget)
        sess.gen = g
        self.submitted += 1
        return sess.sid

    def _vclock(self) -> int:
        """The pool's virtual clock for spans: decode steps elapsed."""
        return self.decode_steps

    def step(self) -> dict:
        """Admit -> decode ``chunk`` tokens for every live session ->
        retire.  Returns a stats snapshot (see :meth:`stats`)."""
        self.last_chunk_s = 0.0             # this step's chunk wall time
        self._admit()
        self._retire()                      # budget-1 sessions finish on admit
        if self.table.active_count():
            self._ensure_pages()            # slack for the next chunk
        if self.table.active_count():
            self._decode_chunk()
            self._retire()
        return self.stats()

    def drain(self) -> dict[int, np.ndarray]:
        """Step until every submitted session is DONE; returns
        ``{sid: (prompt + generated,) int32}`` for the sessions finished
        since the last drain (delivered sessions are evicted from the
        table — memory stays bounded under a continuous request stream)."""
        while not self.table.all_done():
            self.step()
        return self.table.collect_finished()

    def stats(self) -> dict:
        steps = self.decode_steps
        st = {
            "decode_steps": steps,
            "emitted": self.total_emitted,
            # useful (budgeted) *decode* tokens per slot-step — dead rows,
            # chunk overshoot and drained-out tails all count against it
            # (prefill tokens are excluded: they cost no decode step)
            "occupancy": (self._decode_emitted / (steps * self.slots)
                          if steps else 0.0),
            "active": self.table.active_count(),
            # fresh arrivals only; parked sessions are queued but counted
            # separately (they already hold generated state)
            "waiting": (self.table.waiting_count()
                        - self.table.parked_count()),
            "parked": self.table.parked_count(),
            "pages_free": self.alloc.page_free_count(),
            "bank_launches": self.sched.bank_launches,
            "streams_packed": self.sched.streams_packed,
            "prefill_launches": self.prefill_launches,
            "admit_batches": self.admit_batches,
            "preemptions": self.preemptions,
            "page_stalls": self.page_stalls,
            "restores": self.restores,
            "cancels": self.cancels,
            "submitted": self.submitted,
            "admits": self.admits,
        }
        for key in _POOL_GAUGES:            # publish the derived gauges
            self._obs_series[key].set(st[key])
        return st

    # -- admission ----------------------------------------------------------
    def _try_seat(self, need: int) -> int | None:
        """Reserve one slot plus ``need`` sub-pages in the slot's own bank
        — both CPM lookups on the metadata devices.  A slot whose bank is
        out of pages is set aside and the next bank's slots are probed;
        on failure everything probed is released and the caller leaves
        the session queued."""
        held: list[int] = []
        try:
            while True:
                slot = self.alloc.alloc()   # CPM free-slot lookup
                if slot is None:
                    return None
                lo, hi = self._page_range(self._bank_of(slot))
                if self.alloc.alloc_pages(slot, need, lo, hi) is not None:
                    return slot
                held.append(slot)           # bank out of pages; try the next
        finally:
            for s in held:
                self.alloc.free(s)

    def _admit(self) -> None:
        """Admit queued sessions that fit this step.

        Seating is two-resource admission control: a session needs a free
        slot AND its initial page grant (``ceil((prompt+1)/page_size)``
        fresh, the saved page count parked) in the slot's bank.  Sessions
        that do not fit stay queued in FIFO position.  The admission
        *plan* (``repro.serve.gateway.admission``) splits the seated
        window into parked-session restore groups (bucketed by saved page
        count, no prefill) and same-prompt-length buckets of fresh
        sessions; every bucket pays ONE stacked prefill launch + ONE
        scatter program regardless of its size.  With
        ``admit_batching=False`` every group has one member — the strict
        FIFO baseline."""
        from .gateway import admission
        take = min(self._free_hint, self.table.waiting_count())
        if not take:
            return
        seated: dict[int, int] = {}
        for sess in self.table.peek_waiting(take):
            need = (sess.parked.n_pages if sess.phase == PARKED
                    else self._grant0(sess.prompt_len))
            slot = self._try_seat(need)
            if slot is None:
                continue                    # stays queued, FIFO order kept
            seated[sess.sid] = slot
            self._free_hint -= 1
            obs_tracing.instant("pool.page_grant", cat="pool",
                                vstep=self.decode_steps,
                                args={"slot": slot, "pages": need})
        if not seated:
            return
        with obs_tracing.span("pool.admission", cat="pool",
                              vclock=self._vclock,
                              args={"seated": len(seated)}) as sp:
            plan = admission.plan(
                [s for s in self.table.peek_waiting(take)
                 if s.sid in seated],
                batching=self.admit_batching)
            sp.args["restore_groups"] = len(plan.restores)
            sp.args["buckets"] = len(plan.buckets)
            for group in plan.restores:
                self._restore_group(list(group), seated)
            for bucket in plan.buckets:
                self._admit_bucket(list(bucket), seated)

    def _note_admit(self, sess, slot: int) -> None:
        """Host mirrors for one freshly seated session."""
        sess.admit_step = self.decode_steps
        if sess.first_admit_step < 0:
            sess.first_admit_step = self.decode_steps
        self.live[slot] = True
        self._temp[slot] = sess.gen.temperature
        self._topk[slot] = sess.gen.top_k
        self._topp[slot] = sess.gen.top_p

    def _page_table_rows(self, slots: list[int], width: int) -> np.ndarray:
        """Page-table rows for freshly seated ``slots``: each session's
        page list left-aligned into a ``(k, width)`` table, sentinel
        (``total_pages``) beyond the grant."""
        pt = np.full((len(slots), width), self.total_pages, np.int32)
        for i, slot in enumerate(slots):
            ids = self.alloc.pages(slot)
            pt[i, :len(ids)] = ids
        return pt

    def _scatter_token_pages(self, pairs) -> None:
        """Write freshly admitted/restored token rows into their banks:
        ``pairs`` is ``[(slot, row (max_len-or-shorter device/np array),
        row_len)]``; each row is page-chunked onto the slot's page list
        with per-page length registers."""
        per_bank: dict[int, list] = {}
        for slot, row, row_len in pairs:
            per_bank.setdefault(self._bank_of(slot), []).append(
                (slot, row, row_len))
        pg = self.page_size
        for bank_id, members in per_bank.items():
            base = bank_id * self.pages_per_bank
            idx: list[int] = []
            lens: list[int] = []
            chunks = []
            for slot, row, row_len in members:
                ids = self.alloc.pages(slot)
                n_live = self.pages_for(row_len)
                use = ids[:n_live]
                row = jnp.asarray(row, jnp.int32).reshape(-1)
                padded = jnp.zeros((n_live * pg,), jnp.int32)
                padded = padded.at[:row.shape[0]].set(row[:n_live * pg])
                idx += [p - base for p in use]
                lens += [min(pg, max(0, row_len - r * pg))
                         for r in range(n_live)]
                chunks.append(padded.reshape(n_live, pg))
            self.banks[bank_id].scatter(
                jnp.asarray(idx, jnp.int32), jnp.concatenate(chunks, 0),
                jnp.asarray(lens, jnp.int32))

    def _admit_bucket(self, bucket, seated: dict[int, int]) -> None:
        """Check a same-prompt-length bucket of fresh sessions in with one
        batched prefill and one scatter program."""
        engine = self.engine
        k, s = len(bucket), bucket[0].prompt_len
        ctx = obs_tracing.span("pool.admit_bucket", cat="pool",
                               vclock=self._vclock,
                               args={"sessions": k, "prompt_len": s})
        with ctx:
            self._admit_bucket_inner(bucket, seated, k, s)

    def _admit_bucket_inner(self, bucket, seated, k: int, s: int) -> None:
        engine = self.engine
        slots = [seated[sess.sid] for sess in bucket]
        prompts = jnp.stack([sess.prompt for sess in bucket])
        with obs_tracing.span("pool.prefill", cat="pool",
                              vclock=self._vclock,
                              args={"sessions": k, "prompt_len": s}):
            logits, caches1 = engine._prefill(
                engine.params, batch={"tokens": prompts},
                max_len=self.max_len)
        caches1 = kv_cache.broadcast_lens(caches1, k)
        admit = engine._program("pool_admit", self.gen, self._build_admit,
                                s, k, self.slots, self.page_size,
                                self.pages_per_bank)
        self._rng, sub = jax.random.split(self._rng)
        rng = jax.random.fold_in(sub, bucket[0].sid)
        temp = jnp.asarray([se.gen.temperature for se in bucket], jnp.float32)
        topk = jnp.asarray([se.gen.top_k for se in bucket], jnp.int32)
        topp = jnp.asarray([se.gen.top_p for se in bucket], jnp.float32)
        pt = jnp.asarray(self._page_table_rows(slots, self.C))
        idx = jnp.asarray(slots, jnp.int32)
        self.caches, self.pos, self.cur, rows = admit(
            self.caches, caches1, idx, pt, self.pos, self.cur, logits,
            prompts, temp, topk, topp, rng)
        self.tok_lens = self.tok_lens.at[idx].set(s + 1)
        self.prefill_launches += 1
        self.admit_batches += 1
        self.admits += k
        for sess, slot in zip(bucket, slots):
            self.table.activate(sess.sid, self._bank_of(slot), slot)
            self._note_admit(sess, slot)
            sess.emitted = 1                # the prefill token
            self.total_emitted += 1
        self._scatter_token_pages(
            [(slot, rows[i], s + 1) for i, slot in enumerate(slots)])

    def _build_admit(self, s: int, k: int, slots: int, page_size: int,
                     pages_per_bank: int):
        """Jitted batched check-in for ``k`` prompts of length ``s``:
        sample each row's prefill token with its own sampling params,
        scatter the bucket's KV through the page table ``pt (k, C)``
        (global-attn leaves page-chunked into the sub-page pools —
        granted pages are fully rewritten, so nothing from their previous
        tenants survives; per-slot leaves written at rows ``idx``), seed
        pos/cur, and build the token rows."""
        del slots, page_size, pages_per_bank    # cache-key discriminators
        engine, width, cfg = self.engine, self.max_len, self.engine.cfg

        def run(pool_caches, new_caches, idx, pt, pos, cur, logits, prompts,
                temp, topk, topp, rng):
            first = sampling.sample_rows(logits[:, -1], rng, temp, topk,
                                         topp)
            caches = kv_cache.seat_caches(pool_caches, new_caches, cfg,
                                          idx, pt)
            pos = pos.at[idx].set(s)
            cur = cur.at[idx].set(first)
            rows = (jnp.zeros((k, width), jnp.int32)
                    .at[:, :s].set(prompts)
                    .at[jnp.arange(k), s].set(first))
            return caches, pos, cur, rows

        return jax.jit(run) if engine._jit else run

    # -- preemption (mechanism) ---------------------------------------------
    def park(self, sid: int) -> None:
        """Preempt an ACTIVE session: save its LIVE sub-pages into a
        host-side :class:`PageState`, free its slot and whole page list,
        and re-queue it at the FIFO tail for a later token-identical
        restore.  The *policy* — who gets parked, and when — lives in
        ``repro.serve.gateway.preempt``."""
        sess = self.table.get(sid)
        if sess.phase != ACTIVE:
            raise ValueError(f"session {sid} is {sess.phase}, not active")
        if sess.finished:
            raise ValueError(f"session {sid} already hit its budget; "
                             "step() will retire it")
        slot = sess.slot
        row_len = sess.prompt_len + sess.emitted
        n_live = self.pages_for(row_len)
        with obs_tracing.span("pool.park", cat="pool", vclock=self._vclock,
                              args={"sid": sid, "pages": n_live}):
            row = self._read_row(sess)
            pt1 = jnp.asarray(
                self._page_table_rows([slot], n_live)[:, :n_live])
            image = kv_cache.lift_slot(self.caches, self.engine.cfg, slot,
                                       pt1)
            sess.parked = PageState(
                caches=jax.device_get(image), pos=int(self.pos[slot]),
                cur=int(self.cur[slot]), row=np.asarray(row),
                row_len=row_len, n_pages=n_live)
            sess.parks += 1
            self.preemptions += 1
            self.table.park(sid)
            self._release(slot)

    def _release(self, slot: int) -> None:
        """Slot + page list back to the free files, mirrors pinned."""
        self.alloc.free(slot)
        self._free_hint += 1
        self.live[slot] = False
        self.pos = self.pos.at[slot].set(0)
        self.cur = self.cur.at[slot].set(0)
        self.tok_lens = self.tok_lens.at[slot].set(0)

    def _restore_group(self, group, seated: dict[int, int]) -> None:
        """Re-admit parked sessions (all with the same saved page count,
        the planner's grouping key): ONE scatter program re-seats the
        whole group's saved sub-pages/pos/cur images (no prefill — the
        saved pages already hold the history), then each token row
        scatters back onto its new page list."""
        k = len(group)
        states = [sess.parked for sess in group]
        ctx = obs_tracing.span("pool.restore", cat="pool",
                               vclock=self._vclock,
                               args={"sessions": k,
                                     "pages": states[0].n_pages})
        with ctx:
            self._restore_group_inner(group, seated, states)

    def _restore_group_inner(self, group, seated, states) -> None:
        k = len(group)
        slots = [seated[sess.sid] for sess in group]
        n_live = states[0].n_pages
        blocks = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1),
                              *[st.caches["blocks"] for st in states])
        tail = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                            *[st.caches["tail"] for st in states])
        restore = self.engine._program("pool_restore", self.gen,
                                       self._build_restore, k, n_live,
                                       self.slots, self.page_size,
                                       self.pages_per_bank)
        pt = jnp.asarray(self._page_table_rows(slots, n_live))
        idx = jnp.asarray(slots, jnp.int32)
        self.caches, self.pos, self.cur = restore(
            self.caches, blocks, tail, idx, pt, self.pos, self.cur,
            jnp.asarray([st.pos for st in states], jnp.int32),
            jnp.asarray([st.cur for st in states], jnp.int32))
        self.tok_lens = self.tok_lens.at[idx].set(
            jnp.asarray([st.row_len for st in states], jnp.int32))
        for sess, slot in zip(group, slots):
            self.table.activate(sess.sid, self._bank_of(slot), slot)
            self._note_admit(sess, slot)
            sess.parked = None
            self.restores += 1
        self._scatter_token_pages(
            [(slot, st.row, st.row_len)
             for slot, st in zip(slots, states)])

    def _build_restore(self, k: int, n_live: int, slots: int,
                       page_size: int, pages_per_bank: int):
        """Jitted batched re-seat for ``k`` parked sessions with ``n_live``
        saved sub-pages each: write the saved images through the page
        table and restore pos/cur — the decode stream continues exactly
        where preemption cut it."""
        del k, n_live, slots, page_size, pages_per_bank   # cache keys
        engine, cfg = self.engine, self.engine.cfg

        def run(pool_caches, blocks, tail, idx, pt, pos, cur, spos, scur):
            caches = kv_cache.seat_caches(
                pool_caches, {"blocks": blocks, "tail": tail}, cfg, idx, pt)
            return caches, pos.at[idx].set(spos), cur.at[idx].set(scur)

        return jax.jit(run) if engine._jit else run

    def victim_session(self):
        """The allocator's LRU eviction candidate (§7.5 min-over-ticks on
        the metadata device) as a Session, or None when nothing is
        evictable."""
        slot = self.alloc.victim()
        return self.table.at_slot(slot) if slot is not None else None

    # -- cancellation / inspection ------------------------------------------
    def _read_row(self, sess) -> np.ndarray:
        """A session's token content reassembled from its live sub-pages
        (host copy)."""
        row_len = sess.prompt_len + sess.emitted
        n_live = self.pages_for(row_len)
        base = self._bank_of(sess.slot) * self.pages_per_bank
        local = jnp.asarray(
            [p - base for p in self.alloc.pages(sess.slot)[:n_live]],
            jnp.int32)
        pages = np.asarray(self.banks[sess.bank].gather(local))
        return pages.reshape(-1)[:row_len]

    def _row_committed(self, sess) -> int:
        """Summed page-length registers of a session's live sub-pages —
        the bank's own view of how many tokens it holds."""
        row_len = sess.prompt_len + sess.emitted
        base = self._bank_of(sess.slot) * self.pages_per_bank
        local = [p - base for p in
                 self.alloc.pages(sess.slot)[:self.pages_for(row_len)]]
        lens = np.asarray(self.banks[sess.bank].lens)
        return int(lens[np.asarray(local, np.int64)].sum())

    def cancel(self, sid: int) -> np.ndarray:
        """Abort a session in any phase; returns prompt + whatever it
        generated before the cancel.  The tokens stay collectible (DONE)
        until the next drain/collect."""
        sess = self.table.get(sid)
        if sess.phase == DONE:
            return np.asarray(sess.tokens)
        if sess.phase == ACTIVE:
            row = self._read_row(sess)
            self.table.finish(sid, row)
            self._release(sess.slot)
        elif sess.phase == PARKED:
            st = sess.parked
            self.table.finish(sid, np.asarray(st.row[:st.row_len]))
        else:                               # WAITING: nothing ran yet
            self.table.finish(sid, np.asarray(sess.prompt))
        self.cancels += 1
        return np.asarray(sess.tokens)

    def peek_tokens(self, sid: int) -> np.ndarray:
        """Host snapshot of a session's tokens so far (prompt + emitted),
        in any phase — what the gateway's streaming iterator reads."""
        sess = self.table.get(sid)
        if sess.phase == ACTIVE:
            return self._read_row(sess)
        if sess.phase == PARKED:
            return np.asarray(sess.parked.row[:sess.parked.row_len])
        if sess.phase == DONE:
            return np.asarray(sess.tokens)
        return np.asarray(sess.prompt)

    # -- decode -------------------------------------------------------------
    def _ensure_pages(self) -> None:
        """Host-side top-up between chunks: every active session gets
        enough slack pages to cover the next chunk's KV and token writes
        (so a page-boundary crossing never stalls the compiled step).
        When a bank runs dry the *youngest* sessions park — their pages
        free instantly for the older survivors, so the oldest session
        always progresses and a lone session can never livelock (submit
        bounds every session's worst case to one bank's capacity)."""
        order = sorted(self.table.active(),
                       key=lambda s: (s.first_admit_step, s.sid))
        for sess in reversed(order):        # youngest parks first if dry
            need = min(self.C, self.pages_for(
                sess.prompt_len + sess.emitted + self.chunk))
            have = len(self.alloc.pages(sess.slot))
            if need <= have:
                continue
            lo, hi = self._page_range(self._bank_of(sess.slot))
            if self.alloc.alloc_pages(sess.slot, need - have,
                                      lo, hi) is None:
                self.page_stalls += 1
                self.park(sess.sid)
            else:
                obs_tracing.instant(
                    "pool.page_topup", cat="pool", vstep=self.decode_steps,
                    args={"slot": sess.slot, "pages": need - have})

    def _decode_chunk(self) -> None:
        """One compiled program: gather every session's logical row
        through the page table, scan ``chunk`` decode steps, scatter back
        the dirty sub-pages, and commit each bank's tokens via the
        scheduler's packed ``insert -> truncate`` stream — no host
        round-trip inside."""
        engine = self.engine
        active = self.table.active()
        with obs_tracing.span("pool.decode_chunk", cat="pool",
                              vclock=self._vclock,
                              args={"chunk": self.chunk,
                                    "active": len(active)}):
            run = engine._program("pool_chunk", self.gen, self._build_chunk,
                                  self.slots, self.chunk, self.n_banks,
                                  self._bank_backend, self._bank_interpret,
                                  self.page_size, self.pages_per_bank)
            self._rng, sub = jax.random.split(self._rng)
            budget_left = np.zeros((self.slots,), np.int32)
            for sess in active:
                budget_left[sess.slot] = sess.budget - sess.emitted
            pt = np.full((self.slots, self.C), self.total_pages, np.int32)
            for sess in active:
                ids = self.alloc.pages(sess.slot)
                pt[sess.slot, :len(ids)] = ids
            datas = [b.data for b in self.banks]
            lenss = [b.lens for b in self.banks]
            t0 = time.perf_counter()
            (self.cur, self.caches, self.pos, datas, lenss,
             self.tok_lens) = run(
                engine.params, self.cur, self.caches, self.pos,
                jnp.asarray(self.live), jnp.asarray(budget_left),
                jnp.asarray(self._temp), jnp.asarray(self._topk),
                jnp.asarray(self._topp), datas, lenss, jnp.asarray(pt),
                self.tok_lens, sub)
            # dispatch wall time only — no forced device sync here (the
            # tracer must never add one; tests/test_obs.py asserts it)
            self.last_chunk_s = time.perf_counter() - t0
            self._chunk_hist.observe(self.last_chunk_s)
            for b, d, ln in zip(self.banks, datas, lenss):
                b.data, b.lens = d, ln

            for sess in active:             # host-mirror accounting only
                emit = min(self.chunk, sess.budget - sess.emitted)
                sess.emitted += emit
                self.total_emitted += emit
                self._decode_emitted += emit
            self.decode_steps += self.chunk
            self.sched.bank_launches += self.n_banks  # packed commits
            self.sched.streams_packed += len(active)
            obs_tracing.instant("pool.commit_packed", cat="pool",
                                vstep=self.decode_steps,
                                args={"banks": self.n_banks,
                                      "streams": len(active)})

    def _build_chunk(self, slots: int, chunk: int, n_banks: int,
                     bank_backend: str, bank_interpret, page_size: int,
                     pages_per_bank: int):
        """Jitted pooled decode chunk, paged end to end: gather each
        session's FULL logical KV row and token row through the page
        table (``kv_cache.logical_view`` for the KV pools; the
        scalar-prefetch gather kernel for pallas token banks), run an
        inner scan of ``chunk`` ``lm.decode_step`` calls with per-row
        positions (dead rows stay pinned — pos frozen, token 0), commit
        the gathered token rows via the per-bank packed ``insert ->
        truncate`` stream (unchanged logical shapes, so it stays ONE
        fused launch per bank on pallas), then scatter back only the
        DIRTY sub-pages — ranks touched since the chunk began; clean
        pages keep the sentinel and drop.  Rows whose budget ends
        mid-chunk keep decoding into slack; ``emit`` clamps what the
        commit makes visible."""
        del bank_interpret                  # cache-key discriminator: the
        # interpret default below and the commit closures bake it in
        engine, cfg = self.engine, self.engine.cfg
        rpb, C, pg, ppb = (self.rows_per_bank, self.C, self.page_size,
                           pages_per_bank)
        total = self.total_pages
        commits = [self.sched.compiled_commit(b, chunk, rows=rpb)
                   for b in range(n_banks)]
        pallas = bank_backend == "pallas"
        if pallas:
            from repro.kernels import cpm_kernels as K
            interp = self.banks[0]._pallas_interpret()

            def rows_gather(data, idx):
                return K.gather_rows(data, idx, interpret=interp)

            def rows_scatter(data, idx, rows):
                return K.scatter_rows(data, idx, rows, interpret=interp)
        else:
            def rows_gather(data, idx):
                return jnp.take(data, idx, axis=0)

            def rows_scatter(data, idx, rows):
                return data.at[idx].set(rows)    # OOB (sentinel) drops

        def run(params, cur, caches, pos, live, budget_left, temp, topk,
                topp, datas, lenss, page_tbl, tok_lens, rng):
            pos0 = pos
            logical = kv_cache.logical_view(caches, cfg, page_tbl)

            def body(carry, _):
                tok, lcaches, pos, rng = carry
                rng, sub = jax.random.split(rng)
                logits, lcaches = lm.decode_step(params, cfg, tok[:, None],
                                                 lcaches, pos)
                nxt = sampling.sample_rows(logits[:, -1], sub, temp, topk,
                                           topp)
                nxt = jnp.where(live, nxt, 0)
                pos = jnp.where(live, pos + 1, pos)
                return (nxt, lcaches, pos, rng), nxt

            (cur, logical, pos, _), toks = jax.lax.scan(
                body, (cur, logical, pos, rng), None, length=chunk)
            toks = jnp.moveaxis(toks, 0, 1)              # (slots, chunk)
            emit = jnp.where(live, jnp.minimum(budget_left, chunk), 0)
            rank = jnp.arange(C)[None]                   # page ranks
            kv_dirty = rank >= (pos0 // pg)[:, None]     # (slots, C)
            caches = kv_cache.merge_paged(
                caches, logical, cfg,
                jnp.where(kv_dirty, page_tbl, total))
            new_d, new_l, new_tl = [], [], []
            for b in range(n_banks):
                rows = slice(b * rpb, (b + 1) * rpb)
                ptb = page_tbl[rows] - b * ppb           # (rpb, C) local ids
                flat = ptb.reshape(-1)
                lrows = rows_gather(
                    datas[b], jnp.clip(flat, 0, ppb - 1)).reshape(rpb,
                                                                  C * pg)
                lens_b = tok_lens[rows]
                d_rows, l_rows = commits[b](lrows, lens_b, toks[rows],
                                            emit[rows])
                tok_dirty = rank >= (lens_b // pg)[:, None]
                d = rows_scatter(
                    datas[b], jnp.where(tok_dirty, ptb, ppb).reshape(-1),
                    d_rows.reshape(rpb * C, pg))
                plens = jnp.clip(
                    l_rows[:, None] - jnp.arange(C)[None] * pg, 0, pg)
                ln = lenss[b].at[flat].set(plens.reshape(-1).astype(
                    lenss[b].dtype), mode="drop")
                new_d.append(d)
                new_l.append(ln)
                new_tl.append(l_rows)
            return (cur, caches, pos, new_d, new_l,
                    jnp.concatenate(new_tl))

        return jax.jit(run) if engine._jit else run

    # -- retirement ---------------------------------------------------------
    def _retire(self) -> None:
        for sess in list(self.table.active()):
            if not sess.finished:
                continue
            ln = self._row_committed(sess)
            assert ln == sess.prompt_len + sess.emitted, (
                ln, sess.prompt_len, sess.emitted)
            self.table.finish(sess.sid, self._read_row(sess))
            self._release(sess.slot)
