"""Continuous batching over paged CPM banks.

The static engine runs one batch to completion: a single slow request pins
every row's VMEM/HBM for the whole generation.  The session pool replaces
that with the paper's facility view of memory (§4.2): a fixed set of
*pages* — KV-cache rows and token-buffer bank rows — that sessions check
in and out of mid-flight:

  * ``submit``  — queue a prompt + token budget (FIFO);
  * ``step``    — admit waiting sessions into free pages (per-session
    prefill scattered into the pooled KV rows), decode a ``chunk`` of
    tokens for every page in ONE compiled program (an inner scan with
    per-row positions) that also commits each bank's tokens through the
    MASIM packer's pre-collapsed ``insert -> truncate`` stream
    (``MultiBankScheduler.compiled_commit`` — one fused launch per bank
    on pallas), then retire finished sessions and reclaim their pages;
  * ``drain``   — step until every submitted session is done.

Bookkeeping is CPM all the way down: free-page lookups run on the
allocator's metadata device (§6 ``compare`` + Rule-6 drain, ``compact``
for the packed used-page list), token commits are §4.2
``insert``/``truncate`` instruction streams, and pages move through the
scalar-prefetch gather/scatter kernels on pallas banks.  The host keeps
only mirrors (live flags, budgets) — a steady-state step is one compiled
call, no device round-trips.

Correctness contract: under greedy decoding the pool is **token-identical**
to generating each session alone with ``Engine.generate`` — decode math is
row-independent, admission replays the same per-session prefill, and each
session sees exactly the same (token, position, cache) sequence it would
see solo, at any ``chunk`` size (a session finishing mid-chunk keeps
decoding into slack like the static engine's overshoot rows; the commit
clamps to its budget so overshoot tokens never surface).
``tests/test_session_pool.py`` asserts this differentially.  Sampled
decoding is supported (pool-wide sampling params, per-step rng) but makes
no cross-engine identity claim — the rng schedule differs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cpm.pool import CPMBank, MultiBankScheduler, SessionTable, SlotAllocator
from repro.models import lm
from . import kv_cache


class SessionPool:
    """Paged continuous-batching state for one :class:`~repro.serve.Engine`.

    ``slots`` pages are split across ``n_banks`` equal banks (the model
    batch is the concatenation of all banks' rows).  ``gen`` fixes the
    pool-wide sampling parameters; per-session budgets come from
    ``submit``.  ``chunk`` tokens decode per ``step`` inside one compiled
    program — larger chunks amortize dispatch, at the cost of coarser
    admission/retirement granularity.  ``bank_backend``/``bank_interpret``
    route the token banks ("pallas" turns each chunk's bank commit into
    one fused mega-kernel launch and page moves into scalar-prefetch DMA
    kernels).
    """

    def __init__(self, engine, slots: int = 8, n_banks: int = 1, gen=None,
                 chunk: int = 1, bank_backend: str = "reference",
                 bank_interpret: bool | None = None, rng=None):
        from .engine import GenConfig

        if engine.cfg.enc_dec:
            raise NotImplementedError(
                "session pool supports decoder-only models (cross-attention "
                "pages are encoder-owned)")
        if slots <= 0 or n_banks <= 0 or slots % n_banks:
            raise ValueError(f"slots ({slots}) must be a positive multiple "
                             f"of n_banks ({n_banks})")
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.engine = engine
        self.gen = gen if gen is not None else GenConfig()
        self.slots = slots
        self.n_banks = n_banks
        self.rows_per_bank = slots // n_banks
        self.chunk = chunk
        self.max_len = engine.max_len
        self._bank_backend = bank_backend
        self._bank_interpret = bank_interpret

        self.alloc = SlotAllocator(slots)
        self.banks = [CPMBank(self.rows_per_bank, self.max_len,
                              backend=bank_backend,
                              interpret=bank_interpret)
                      for _ in range(n_banks)]
        self.sched = MultiBankScheduler(self.banks)
        self.table = SessionTable()

        caches = lm.init_caches(engine.cfg, slots, self.max_len)
        self.caches = kv_cache.broadcast_lens(caches, slots)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros((slots,), bool)
        self._free_hint = slots            # host mirror of the free count
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)

        self.decode_steps = 0
        self.total_emitted = 0
        self._decode_emitted = 0           # excludes prefill tokens

    # -- public API ---------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int | None = None) -> int:
        """Queue one session; returns its id.  ``max_new_tokens`` defaults
        to the pool GenConfig's budget."""
        tokens = jnp.asarray(tokens, jnp.int32).reshape(-1)
        s = int(tokens.shape[0])
        budget = (self.gen.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if s < 1:
            raise ValueError("empty prompt")
        if budget > 0 and s + budget > self.max_len:
            raise ValueError(
                f"prompt ({s}) + budget ({budget}) exceeds max_len "
                f"({self.max_len}); pages are max_len wide")
        sess = self.table.add(tokens, s, budget)
        if budget <= 0:                     # nothing to generate
            self.table.finish(sess.sid, np.asarray(tokens))
        return sess.sid

    def step(self) -> dict:
        """Admit -> decode ``chunk`` tokens for every live page -> retire.

        Returns a stats snapshot (see :meth:`stats`)."""
        self._admit()
        self._retire()                      # budget-1 sessions finish on admit
        if self.table.active_count():
            self._decode_chunk()
            self._retire()
        return self.stats()

    def drain(self) -> dict[int, np.ndarray]:
        """Step until every submitted session is DONE; returns
        ``{sid: (prompt + generated,) int32}`` for the sessions finished
        since the last drain (delivered sessions are evicted from the
        table — memory stays bounded under a continuous request stream)."""
        while not self.table.all_done():
            self.step()
        return self.table.collect_finished()

    def stats(self) -> dict:
        steps = self.decode_steps
        return {
            "decode_steps": steps,
            "emitted": self.total_emitted,
            # useful (budgeted) *decode* tokens per slot-step — dead pages,
            # chunk overshoot and drained-out tails all count against it
            # (prefill tokens are excluded: they cost no decode step)
            "occupancy": (self._decode_emitted / (steps * self.slots)
                          if steps else 0.0),
            "active": self.table.active_count(),
            "waiting": self.table.waiting_count(),
            "bank_launches": self.sched.bank_launches,
            "streams_packed": self.sched.streams_packed,
        }

    # -- admission ----------------------------------------------------------
    def _admit(self) -> None:
        engine = self.engine
        while self._free_hint and self.table.next_waiting() is not None:
            sess = self.table.next_waiting()
            slot = self.alloc.alloc()       # CPM free-page lookup
            assert slot is not None, "free-count mirror out of sync"
            self._free_hint -= 1
            bank_id = slot // self.rows_per_bank
            local = slot % self.rows_per_bank
            self.table.activate(sess.sid, bank_id, slot)

            logits, caches1 = engine._prefill(
                engine.params, batch={"tokens": sess.prompt[None]},
                max_len=self.max_len)
            caches1 = kv_cache.broadcast_lens(caches1, 1)
            admit = engine._program("pool_admit", self.gen,
                                    self._build_admit, sess.prompt_len,
                                    self.slots)
            self._rng, sub = jax.random.split(self._rng)
            rng = jax.random.fold_in(sub, sess.sid)
            self.caches, self.pos, self.cur, row = admit(
                self.caches, caches1, jnp.asarray(slot, jnp.int32),
                self.pos, self.cur, logits, sess.prompt, rng)
            self.banks[bank_id].scatter(
                jnp.asarray([local], jnp.int32), row[None],
                jnp.asarray([sess.prompt_len + 1], jnp.int32))
            sess.emitted = 1                # the prefill token
            self.total_emitted += 1
            self.live[slot] = True

    def _build_admit(self, s: int, slots: int):
        """Jitted page check-in for a prompt of length ``s``: sample the
        prefill token, scatter the session's KV into pool row ``slot``
        (blocks batch axis 1, tail axis 0 — whole row replaced, nothing
        from the page's previous tenant survives), seed pos/cur, and build
        the token-bank row."""
        engine, gen, width = self.engine, self.gen, self.max_len

        def run(pool_caches, new_caches, slot, pos, cur, logits, prompt,
                rng):
            first = engine._sample(logits[:, -1], gen, rng)[0]

            def wr_b(p, n):
                return p.at[:, slot].set(n[:, 0].astype(p.dtype))

            def wr_t(p, n):
                return p.at[slot].set(n[0].astype(p.dtype))

            caches = {
                "blocks": jax.tree.map(wr_b, pool_caches["blocks"],
                                       new_caches["blocks"]),
                "tail": jax.tree.map(wr_t, pool_caches["tail"],
                                     new_caches["tail"]),
            }
            pos = pos.at[slot].set(s)
            cur = cur.at[slot].set(first)
            row = (jnp.zeros((width,), jnp.int32)
                   .at[:s].set(prompt).at[s].set(first))
            return caches, pos, cur, row

        return jax.jit(run) if engine._jit else run

    # -- decode -------------------------------------------------------------
    def _decode_chunk(self) -> None:
        """One compiled program: scan ``chunk`` decode steps over every
        page, then commit each bank's tokens via the scheduler's packed
        ``insert -> truncate`` stream — no host round-trip inside."""
        engine = self.engine
        run = engine._program("pool_chunk", self.gen, self._build_chunk,
                              self.slots, self.chunk, self.n_banks,
                              self._bank_backend, self._bank_interpret)
        self._rng, sub = jax.random.split(self._rng)
        budget_left = np.zeros((self.slots,), np.int32)
        for sess in self.table.active():
            budget_left[sess.slot] = sess.budget - sess.emitted
        datas = [b.data for b in self.banks]
        lenss = [b.lens for b in self.banks]
        self.cur, self.caches, self.pos, datas, lenss = run(
            engine.params, self.cur, self.caches, self.pos,
            jnp.asarray(self.live), jnp.asarray(budget_left), datas, lenss,
            sub)
        for b, d, ln in zip(self.banks, datas, lenss):
            b.data, b.lens = d, ln

        active = self.table.active()
        for sess in active:                 # host-mirror accounting only
            emit = min(self.chunk, sess.budget - sess.emitted)
            sess.emitted += emit
            self.total_emitted += emit
            self._decode_emitted += emit
        self.decode_steps += self.chunk
        self.sched.bank_launches += self.n_banks    # packed commit launches
        self.sched.streams_packed += len(active)

    def _build_chunk(self, slots: int, chunk: int, n_banks: int,
                     bank_backend: str, bank_interpret):
        """Jitted pooled decode chunk: an inner scan of ``chunk``
        ``lm.decode_step`` calls with per-row positions (dead pages stay
        pinned — pos frozen, token 0 — and only write their own row),
        followed by the per-bank packed commit.  Rows whose budget ends
        mid-chunk keep decoding into slack; ``emit`` clamps what the
        commit makes visible."""
        del bank_backend, bank_interpret    # cache-key discriminators: the
        # compiled_commit closures below bake the bank routing in
        engine, gen, cfg = self.engine, self.gen, self.engine.cfg
        rpb = self.rows_per_bank
        commits = [self.sched.compiled_commit(b, chunk)
                   for b in range(n_banks)]

        def run(params, cur, caches, pos, live, budget_left, datas, lenss,
                rng):
            def body(carry, _):
                tok, caches, pos, rng = carry
                rng, sub = jax.random.split(rng)
                logits, caches = lm.decode_step(params, cfg, tok[:, None],
                                                caches, pos)
                nxt = engine._sample(logits[:, -1], gen, sub)
                nxt = jnp.where(live, nxt, 0)
                pos = jnp.where(live, pos + 1, pos)
                return (nxt, caches, pos, rng), nxt

            (cur, caches, pos, _), toks = jax.lax.scan(
                body, (cur, caches, pos, rng), None, length=chunk)
            toks = jnp.moveaxis(toks, 0, 1)              # (slots, chunk)
            emit = jnp.where(live, jnp.minimum(budget_left, chunk), 0)
            new_d, new_l = [], []
            for b in range(n_banks):
                rows = slice(b * rpb, (b + 1) * rpb)
                d, ln = commits[b](datas[b], lenss[b], toks[rows],
                                   emit[rows])
                new_d.append(d)
                new_l.append(ln)
            return cur, caches, pos, new_d, new_l

        return jax.jit(run) if engine._jit else run

    # -- retirement ---------------------------------------------------------
    def _retire(self) -> None:
        for sess in list(self.table.active()):
            if not sess.finished:
                continue
            bank = self.banks[sess.bank]
            local = sess.slot % self.rows_per_bank
            row, ln = bank.read_row(local)
            assert ln == sess.prompt_len + sess.emitted, (
                ln, sess.prompt_len, sess.emitted)
            self.table.finish(sess.sid, row[:ln])
            self.alloc.free(sess.slot)      # page back to the free list
            self._free_hint += 1
            self.live[sess.slot] = False
            # pin the dead page: frozen position, token 0 — its decode
            # writes stay inside its own (soon-to-be-recycled) row
            self.pos = self.pos.at[sess.slot].set(0)
            self.cur = self.cur.at[sess.slot].set(0)
