"""Scan-based batched serving engine with CPM-powered extras.

Decode is a single compiled ``jax.lax.scan`` over fixed-shape state
(current token, KV/recurrent caches, per-row positions, rng): the host
launches ONE XLA program per generate call and syncs once at the end —
zero per-token host round-trips, the serving analogue of the paper's
"compute where the data lives" discipline.

Speculative decoding (prompt-lookup drafts from the paper's
content-searchable memory, §5) works at any batch size:

  * the trailing n-gram of every row is matched against that row's
    generated context concurrently (``searchable.ngram_lookup`` under
    ``vmap`` — ~n concurrent compare steps per the paper);
  * the whole ``draft_len``-token draft is verified in ONE teacher-forced
    forward (``lm.decode_multi``, a scan inside one compiled program);
  * acceptance per row is the searchable carry chain
    (``searchable.verify_draft``);
  * KV rollback after partial acceptance is a vectorized per-row
    ``kv_cache.truncate`` (global attention: O(1) length clamp) plus
    per-row snapshot selection for recurrent states and local-window
    rings (``lm.rollback_caches``).

Rows accept different draft prefixes, so positions and cache lengths are
per-row vectors throughout (``kv_cache.broadcast_lens``).  Rows that
reach their token budget early keep decoding into cache slack until the
slowest row finishes; their extra tokens never reach the output buffer
and never contaminate other rows (all cross-row state is batched
element-wise).  Stats clip the final overshooting round, so
``accepted``/``emitted`` count only tokens actually returned.

Sampling truncation via content-comparable thresholds (sampling.py);
KV management via content-movable ops (kv_cache.py).  The old
step-by-step path lives on as the differential-test oracle in
``reference.py``.

Beyond the static ``generate`` batch, the engine serves a *stream* of
requests through the paged session pool (``session_pool.py``):
``submit``/``step``/``drain`` admit sessions into free KV/token pages
mid-flight, decode one batched step across every live page, and retire
finished sessions so their pages go straight back to the allocator —
continuous batching, token-identical (greedy) to per-session static
generation.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.cpm.reference import searchable
from repro.models import lm
from . import kv_cache, program_paths, sampling


@dataclasses.dataclass
class GenConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 => greedy
    top_k: int = 0
    top_p: float = 0.0
    ngram_spec: int = 0                # >0: prompt-lookup draft length
    ngram_len: int = 3                 # trailing n-gram matched for drafts

    def _key(self):
        return (self.max_new_tokens, self.temperature, self.top_k,
                self.top_p, self.ngram_spec, self.ngram_len)


class Engine:
    """Batched scan engine (static batch, fixed shapes, one program/call)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 jit: bool = True, cpm_backend: str = "reference",
                 cpm_interpret: bool | None = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._jit = jit
        # backend for the CPM commit path (token-buffer splice):
        # "reference" keeps the one-scatter XLA lowering; "pallas" commits
        # each round through the recorded program as ONE fused_stream
        # mega-kernel launch (see _build_commit)
        self.cpm_backend = cpm_backend
        self.cpm_interpret = cpm_interpret

        def maybe_jit(fn, **kw):
            return jax.jit(fn, **kw) if jit else fn

        self._prefill = maybe_jit(functools.partial(lm.prefill, cfg=cfg),
                                  static_argnames=("max_len",))
        # draft verification: ONE forward over all draft tokens per round
        self._decode_multi = maybe_jit(functools.partial(lm.decode_multi,
                                                         cfg=cfg))
        self._programs: dict = {}
        self._pool = None              # default continuous-batching pool

    # -- public API --------------------------------------------------------

    def generate(self, batch: dict, gen: GenConfig, rng=None):
        """Returns (tokens (B, prompt+new), stats).

        stats: ``accepted`` / ``proposed`` draft-token counts (clipped to
        the token budget), ``emitted`` total new tokens, ``rounds``
        speculative rounds, ``acceptance_rate`` = accepted/proposed.
        """
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        tokens = jnp.asarray(batch["tokens"], jnp.int32)
        b, s = tokens.shape
        if gen.max_new_tokens <= 0:
            return tokens, {"accepted": 0, "proposed": 0, "rounds": 0,
                            "emitted": 0, "acceptance_rate": 0.0}
        logits, caches = self._prefill(self.params, batch=batch,
                                       max_len=self.max_len)
        caches = kv_cache.broadcast_lens(caches, b)
        pos = jnp.full((b,), s, jnp.int32)
        spec = (gen.ngram_spec > 0 and gen.temperature <= 0
                and s >= min(gen.ngram_len, s - 1) + 2)
        if spec:
            out, stats = self._generate_spec(tokens, logits, caches, pos, gen)
        else:
            out, stats = self._generate_scan(tokens, logits, caches, pos,
                                             gen, rng)
        prop = stats["proposed"]
        stats["acceptance_rate"] = stats["accepted"] / prop if prop else 0.0
        return out[:, : s + gen.max_new_tokens], stats

    def _sample(self, logits, gen: GenConfig, rng):
        return sampling.sample(logits, rng, gen.temperature, gen.top_k,
                               gen.top_p)

    # -- non-speculative: one scan program, zero per-token syncs -----------

    def _generate_scan(self, tokens, logits, caches, pos, gen: GenConfig,
                       rng):
        b, s = tokens.shape
        run = self._program("scan", gen, self._build_scan, gen)
        seq, _, _ = run(self.params, logits, caches, pos, rng)
        out = jnp.concatenate([tokens, seq], axis=1)
        return out, {"accepted": 0, "proposed": 0, "rounds": 0,
                     "emitted": b * gen.max_new_tokens}

    def _build_scan(self, gen: GenConfig):
        steps = gen.max_new_tokens
        cfg = self.cfg

        def run(params, logits0, caches, pos, rng):
            first = self._sample(logits0[:, -1], gen, rng)

            def body(carry, _):
                tok, caches, pos, rng = carry
                rng, sub = jax.random.split(rng)
                logits, caches = lm.decode_step(params, cfg, tok[:, None],
                                                caches, pos)
                nxt = self._sample(logits[:, -1], gen, sub)
                return (nxt, caches, pos + 1, rng), nxt

            (_, caches, pos, _), toks = jax.lax.scan(
                body, (first, caches, pos, rng), None, length=steps - 1)
            seq = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                                  axis=1)
            return seq, caches, pos

        return jax.jit(run) if self._jit else run

    # -- batched prompt-lookup speculative decoding ------------------------

    def _generate_spec(self, tokens, logits, caches, pos, gen: GenConfig):
        b, s = tokens.shape
        max_new = gen.max_new_tokens
        # an active row's last verify round can write up to draft_len - 1
        # KV slots past its budget; without this slack the global-attn
        # slot write (pos % slots) would wrap onto live prompt KV
        need = s + max_new + gen.ngram_spec - 1
        if self.max_len < need:
            raise ValueError(
                f"speculative decoding needs max_len >= prompt + "
                f"max_new_tokens + ngram_spec - 1 = {need}, got "
                f"{self.max_len}")
        buf = jnp.zeros((b, s + max_new), jnp.int32).at[:, :s].set(tokens)
        buf = buf.at[:, s].set(sampling.greedy(logits[:, -1]))
        n_new = jnp.ones((b,), jnp.int32)
        stats = {"accepted": 0, "proposed": 0, "rounds": 0, "emitted": b}

        draft_prog = self._program("draft", gen, self._build_draft, s, gen)
        commit_prog = self._program("commit", gen, self._build_commit,
                                    s, gen)
        while int(jnp.min(n_new)) < max_new:             # one sync per round
            seq, draft = draft_prog(buf, n_new)
            logits, caches, snaps = self._decode_multi(
                self.params, tokens=seq, caches=caches, pos=pos)
            buf, n_new, caches, pos, acc, prop, emit = commit_prog(
                buf, n_new, caches, snaps, draft, logits, pos)
            stats["accepted"] += int(acc)
            stats["proposed"] += int(prop)
            stats["emitted"] += int(emit)
            stats["rounds"] += 1
        return buf, stats

    def _build_draft(self, s: int, gen: GenConfig):
        """(buf, n_new) -> (seq (B,T) verification input, draft (B,T))."""
        draft_len = gen.ngram_spec
        n = min(gen.ngram_len, s - 1)

        def run(buf, n_new):
            b, cap = buf.shape
            rows = jnp.arange(b)
            total = s + n_new                            # (B,) live lengths
            # trailing n-gram per row
            gidx = total[:, None] - n + jnp.arange(n)[None]
            ngram = buf[rows[:, None], gidx]
            # search context = live tokens minus the final one (the trailing
            # self-match must not count); dead slots get -1, matching nothing
            live = jnp.arange(cap)[None] < (total - 1)[:, None]
            ctx = jnp.where(live, buf, -1)
            starts, valid = jax.vmap(
                functools.partial(searchable.ngram_lookup, max_out=1))(
                    ctx, ngram)
            start, ok = starts[:, 0], valid[:, 0]
            # draft = continuation after the earliest historical occurrence,
            # zero-padded past the live region (degenerate rows draft zeros)
            didx = start[:, None] + jnp.arange(draft_len)[None]
            vals = buf[rows[:, None], jnp.minimum(didx, cap - 1)]
            draft = jnp.where(ok[:, None] & (didx < total[:, None]), vals, 0)
            last = buf[rows, total - 1]
            seq = jnp.concatenate([last[:, None], draft[:, :-1]], axis=1)
            return seq, draft

        return jax.jit(run) if self._jit else run

    def _build_commit(self, s: int, gen: GenConfig):
        """Acceptance, rollback, and output-buffer commit for one round.

        The paper-side sequence — draft verify (§5 carry chain) -> KV
        rollback (§4.2 truncate) -> token splice (§4.2 insert) — commits
        through a CPM program (``serve.program_paths``) on the pallas/mesh
        backends: the insert+truncate pair on the token buffer is one
        fusion group, so a commit round on pallas is a single mega-kernel
        launch instead of per-op dispatch.  On the default reference
        backend the same splice stays a one-scatter XLA op (no launches to
        fuse, and the scatter touches only draft_len slots).  Both paths
        are token-identical within the returned live region
        (``tests/test_program.py`` asserts engine-output equality).
        """
        draft_len, max_new = gen.ngram_spec, gen.max_new_tokens
        cfg = self.cfg

        def run(buf, n_new, caches, snaps, draft, logits, pos):
            preds = sampling.greedy(logits)              # (B, T) greedy
            n_acc = searchable.verify_draft(draft, preds)         # (B,)
            n_emit = jnp.minimum(n_acc + 1, draft_len)   # always >= 1
            # rollback: snapshots for recurrent/ring state, then the
            # vectorized per-row length truncation for global-attn KV
            caches = lm.rollback_caches(cfg, caches, snaps, n_emit - 1)
            new_pos = pos + n_emit
            caches = kv_cache.truncate(caches, new_pos)
            # commit emitted tokens (= preds over the kept prefix) at
            # per-row offsets; rows past their budget write nothing that
            # the returned live region can see
            remaining = jnp.maximum(max_new - n_new, 0)
            emit_n = jnp.minimum(n_emit, remaining)
            if self.cpm_backend == "reference":
                # XLA-native realization of the same §4.2 splice: one
                # scatter touching draft_len slots.  The recorded program
                # rolls whole rows — equivalent within the live region but
                # ~10x the vector work (bench PF_commit_program_b8), and
                # its fusion win only exists where launches cost something.
                b, cap = buf.shape
                rows = jnp.arange(b)
                tidx = jnp.arange(draft_len)[None]
                widx = jnp.where(tidx < emit_n[:, None],
                                 s + n_new[:, None] + tidx, cap)
                buf = buf.at[rows[:, None], widx].set(preds, mode="drop")
                n_new = n_new + emit_n
            else:
                buf, new_used = program_paths.commit_tokens(
                    buf, s + n_new, preds, emit_n,
                    backend=self.cpm_backend, interpret=self.cpm_interpret)
                n_new = new_used - s
            acc = jnp.sum(jnp.minimum(n_acc, emit_n))
            # proposed, like accepted, counts only draft tokens within the
            # budget, so acceptance_rate reflects returned tokens
            prop = jnp.sum(jnp.minimum(draft_len, remaining))
            return buf, n_new, caches, new_pos, acc, prop, jnp.sum(emit_n)

        return jax.jit(run) if self._jit else run

    # -- continuous batching (paged session pool) --------------------------

    def session_pool(self, slots: int = 8, n_banks: int = 1, gen=None,
                     **kw):
        """A fresh continuous-batching pool over this engine's weights:
        ``slots`` KV/token pages split across ``n_banks`` CPM banks (see
        ``repro.serve.session_pool``).  Compiled programs are shared
        through this engine's cache, so pools are cheap to recreate."""
        from .session_pool import SessionPool
        return SessionPool(self, slots=slots, n_banks=n_banks, gen=gen,
                           **kw)

    def submit(self, tokens, max_new_tokens: int | None = None, **pool_kw):
        """Queue one request on the engine's default session pool (created
        on first use; ``pool_kw`` configures that first creation).
        Returns the session id — ``step()``/``drain()`` advance it."""
        if getattr(self, "_pool", None) is None:
            self._pool = self.session_pool(**pool_kw)
        elif pool_kw:
            raise ValueError("default pool already exists; use "
                             "session_pool() for a differently-shaped one")
        return self._pool.submit(tokens, max_new_tokens)

    def step(self):
        """One continuous-batching step on the default pool: admit waiting
        sessions into free pages, decode one token per live page, retire
        finished sessions.  Returns the pool's stats snapshot."""
        if getattr(self, "_pool", None) is None:
            raise RuntimeError("no sessions submitted")
        return self._pool.step()

    def drain(self):
        """Run the default pool to completion; returns
        ``{session_id: (prompt + generated,) tokens}``."""
        if getattr(self, "_pool", None) is None:
            raise RuntimeError("no sessions submitted")
        out = self._pool.drain()
        return out

    # -- compiled-program cache -------------------------------------------

    def _program(self, name, gen: GenConfig, builder, *args):
        """Compiled-program cache.

        Builders close over *static* shape parameters (prompt length, pool
        row count) that ``jax.jit`` cannot recover by retracing, so the
        cache key must cover them: it is ``(name, GenConfig key, static
        builder args)``.  Keying on the name alone collided as soon as the
        session pool drove varying shapes through one engine — two pools
        (or two prompt lengths) sharing a name must compile separately.
        GenConfig args contribute via ``_key()``; other non-hashable args
        are rejected rather than silently collapsed into one cache line.
        """
        def static(a):
            if isinstance(a, GenConfig):
                return a._key()
            if isinstance(a, (int, float, str, bool, tuple, frozenset,
                              type(None))):
                return a
            raise TypeError(
                f"_program builder arg {a!r} is not statically hashable; "
                f"pass dynamic values to the compiled function, not the "
                f"builder")

        key = (name, gen._key() if gen is not None else None,
               tuple(static(a) for a in args))
        if key not in self._programs:
            self._programs[key] = builder(*args)
        return self._programs[key]
