from . import engine, kv_cache, sampling
from .engine import Engine, GenConfig

__all__ = ["engine", "kv_cache", "sampling", "Engine", "GenConfig"]
