from . import (engine, gateway, kv_cache, program_paths, reference,
               sampling, session_pool)
from .engine import Engine, GenConfig
from .gateway import Gateway
from .reference import ReferenceEngine
from .session_pool import SessionPool

__all__ = ["engine", "gateway", "kv_cache", "program_paths", "reference",
           "sampling", "session_pool", "Engine", "GenConfig", "Gateway",
           "ReferenceEngine", "SessionPool"]
