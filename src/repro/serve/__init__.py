from . import engine, kv_cache, program_paths, reference, sampling
from .engine import Engine, GenConfig
from .reference import ReferenceEngine

__all__ = ["engine", "kv_cache", "program_paths", "reference", "sampling",
           "Engine", "GenConfig", "ReferenceEngine"]
