from . import (engine, gateway, http, kv_cache, program_paths, reference,
               sampling, session_pool)
from .engine import Engine, GenConfig
from .gateway import Gateway
from .http import HttpFrontend, SSEDecoder
from .reference import ReferenceEngine
from .session_pool import SessionPool

__all__ = ["engine", "gateway", "http", "kv_cache", "program_paths",
           "reference", "sampling", "session_pool", "Engine", "GenConfig",
           "Gateway", "HttpFrontend", "SSEDecoder", "ReferenceEngine",
           "SessionPool"]
