from . import engine, kv_cache, program_paths, reference, sampling, session_pool
from .engine import Engine, GenConfig
from .reference import ReferenceEngine
from .session_pool import SessionPool

__all__ = ["engine", "kv_cache", "program_paths", "reference", "sampling",
           "session_pool", "Engine", "GenConfig", "ReferenceEngine",
           "SessionPool"]
