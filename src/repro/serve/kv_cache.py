"""KV-cache management as content-movable memory (paper §4).

The cache lives on-device; every management op is a constant number of
concurrent vector ops executed where the data is stored — never a host
round-trip over the bus.  This is the paper's thesis applied to serving:

  * sliding-window eviction  = ring overwrite (O(1), `attention_step`)
  * speculative rollback     = range delete (`truncate`)
  * hole compaction          = stable compaction (`compact_slots`)
  * prefix-cache splice      = range insert (`splice_prefix`)
  * paged residency          = sub-page pools + page-table gather/scatter
                               (`paged_pool` / `logical_view` /
                               `merge_paged` / `seat_caches` / `lift_slot`)

The paged helpers implement the serving pool's vLLM-style layout: every
*global*-attention k/v leaf is stored as a pool of fixed-size sub-pages
(``(..., n_pages, KVH, page_size, dh)``) instead of one ``max_len`` row
per session, and a per-slot page table ``(B, C)`` (``C = max_len //
page_size``; entries ``>= n_pages`` are sentinels) maps each session's
logical row onto its page list.  Local-window rings, recurrent states
and ``len`` leaves stay per-slot — only the worst-case-sized global
caches are paged.  Gathers reassemble the FULL logical width (attention
then runs bit-identically to the un-paged layout; sentinel pages clamp
to an arbitrary page and are excluded by the ``len`` mask), scatters
write back only the pages named by the (dirty-masked) table — sentinel
entries drop.

All ops treat the slot axis (-2 of (B, KVH, S, dh)) as the PE address axis.
The insert/truncate paths run through :class:`repro.cpm.CPMArray` — the
cache is literally a CPM device whose ``used_len`` is the `len` leaf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cpm import CPMArray


def _map_kv(cache_tree, fn):
    """Apply fn(k_or_v, leaf_len_ctx) to every attn k/v leaf in a cache tree."""
    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node:
                return dict(node, k=fn(node["k"]), v=fn(node["v"]))
            return {kk: walk(vv) for kk, vv in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(x) for x in node]
            return type(node)(t)
        return node
    return walk(cache_tree)


def truncate(caches, new_len):
    """Speculative-decode rollback: drop cache entries at slots >= new_len.

    A range delete in content-movable terms; entries need not be zeroed
    (the `len` mask excludes them) — we update lengths only, O(1).

    ``new_len`` may be a scalar or a per-row ``(B,)`` vector: after batched
    speculative decoding each row accepts a different draft prefix, so each
    row rolls back to its own length.  ``len`` leaves broadcast against it
    (scalar, ``(B,)``, or rep-stacked ``(R, B)`` all work).

    Cross-attention caches (``cross_kv``) hold *encoder* content — their
    length is the encoder sequence, not a decoder position — so they are
    never truncated.
    """
    new_len = jnp.asarray(new_len, jnp.int32)

    def walk(node):
        if isinstance(node, dict):
            if "len" in node and "k" in node:
                # CPMArray.truncate semantics on the slot axis: lengths only,
                # data stays put (the used-region mask excludes it)
                return dict(node, len=jnp.minimum(node["len"], new_len))
            return {kk: vv if kk == "cross_kv" else walk(vv)
                    for kk, vv in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)([walk(x) for x in node])
        return node
    return walk(caches)


def broadcast_lens(caches, batch: int):
    """Give every ``len`` leaf a trailing per-row ``(batch,)`` axis.

    Prefill produces scalar lengths (all rows equal).  The batched engine
    needs per-row lengths — rows diverge after partial draft acceptance —
    and shape-stable scan carries (``attention_step`` returns ``pos + 1``
    which is ``(B,)`` under per-row decode).  A scalar leaf becomes
    ``(B,)``, a rep-stacked ``(R,)`` leaf becomes ``(R, B)``.

    Idempotent: a leaf that already carries the batch axis is left
    untouched, so a second call cannot silently stack another batch axis
    onto every length (scalar -> ``(B,)`` -> ``(B, B)``).  The
    discriminator is the sibling data leaf in the same cache node
    (attention ``k`` or recurrent ``C``), which always has exactly three
    trailing content dims — a broadcast length has ``sib.ndim - 3`` dims,
    a fresh one ``sib.ndim - 4`` — so even a rep-stacked ``(R,)`` leaf
    with ``R == batch`` is classified correctly.  Nodes without such a
    sibling fall back to the trailing-axis-equals-``batch`` test.
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            sib = node.get("k", node.get("C"))
            for kk, vv in node.items():
                if kk == "len":
                    lv = jnp.asarray(vv, jnp.int32)
                    if sib is not None:
                        done = lv.ndim == jnp.ndim(sib) - 3
                    else:
                        done = lv.ndim >= 1 and lv.shape[-1] == batch
                    out[kk] = lv if done else jnp.broadcast_to(
                        lv[..., None], lv.shape + (batch,))
                else:
                    out[kk] = walk(vv)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)([walk(x) for x in node])
        return node
    return walk(caches)


def attn_sites(cfg) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Positions of the *global*-attention cache nodes in a pool tree —
    (unit indices into ``blocks``, indices into ``tail``).  These are the
    leaves the paged layout replaces; everything else stays per-slot."""
    from repro.models import lm
    unit, _, tail = lm._layout(cfg)
    return (tuple(u for u, kind in enumerate(unit) if kind == "attn"),
            tuple(t for t, kind in enumerate(tail) if kind == "attn"))


def _map_attn_nodes(caches, cfg, site_fn):
    """Rebuild a cache tree with ``site_fn(attn_node, stacked)`` applied to
    every global-attention node (``stacked``: leading rep axis or not);
    other nodes pass through untouched."""
    ub, ut = attn_sites(cfg)
    blocks = [dict(node, attn=site_fn(node["attn"], True))
              if u in ub else node
              for u, node in enumerate(caches["blocks"])]
    tail = [dict(node, attn=site_fn(node["attn"], False))
            if t in ut else node
            for t, node in enumerate(caches["tail"])]
    return {"blocks": blocks, "tail": tail}


def paged_pool(caches, cfg, n_pages: int, page_size: int):
    """Re-layout zero-initialized decode caches for paged serving: every
    global-attn k/v leaf ``(..., B, KVH, max_len, dh)`` becomes a sub-page
    pool ``(..., n_pages, KVH, page_size, dh)``; ``len`` leaves and all
    non-global nodes keep their per-slot shapes."""
    def site(a, stacked):
        k = a["k"]
        if stacked:
            r, _, kvh, _, dh = k.shape
            shp = (r, n_pages, kvh, page_size, dh)
        else:
            _, kvh, _, dh = k.shape
            shp = (n_pages, kvh, page_size, dh)
        return dict(a, k=jnp.zeros(shp, k.dtype), v=jnp.zeros(shp, k.dtype))
    return _map_attn_nodes(caches, cfg, site)


def _gather_leaf(pool_leaf, pt, stacked: bool):
    """Pool pages -> logical rows: ``(..., P, KVH, pg, dh)`` gathered at
    ``pt (B, C)`` and flattened to ``(..., B, KVH, C*pg, dh)``.  Sentinel
    entries clamp to the last page — their content is masked downstream by
    the per-row ``len``."""
    n_pages = pool_leaf.shape[1] if stacked else pool_leaf.shape[0]
    ptc = jnp.clip(jnp.asarray(pt, jnp.int32), 0, n_pages - 1)
    if stacked:
        g = jnp.moveaxis(pool_leaf[:, ptc], 3, 2)  # (R, B, KVH, C, pg, dh)
        r, b, kvh, c, pg, dh = g.shape
        return g.reshape(r, b, kvh, c * pg, dh)
    g = jnp.moveaxis(pool_leaf[ptc], 2, 1)         # (B, KVH, C, pg, dh)
    b, kvh, c, pg, dh = g.shape
    return g.reshape(b, kvh, c * pg, dh)


def _scatter_leaf(pool_leaf, rows_leaf, pt, stacked: bool):
    """Logical rows -> pool pages: the inverse of :func:`_gather_leaf`;
    ``pt`` entries ``>= n_pages`` (sentinels / clean pages) drop."""
    pt = jnp.asarray(pt, jnp.int32)
    c = pt.shape[-1]
    if stacked:
        r, b, kvh, w, dh = rows_leaf.shape
        vals = rows_leaf.reshape(r, b, kvh, c, w // c, dh)
        vals = jnp.moveaxis(vals, 2, 3)            # (R, B, C, KVH, pg, dh)
        return pool_leaf.at[:, pt].set(vals.astype(pool_leaf.dtype),
                                       mode="drop")
    b, kvh, w, dh = rows_leaf.shape
    vals = rows_leaf.reshape(b, kvh, c, w // c, dh)
    vals = jnp.moveaxis(vals, 1, 2)                # (B, C, KVH, pg, dh)
    return pool_leaf.at[pt].set(vals.astype(pool_leaf.dtype), mode="drop")


def logical_view(pool_caches, cfg, pt):
    """The decode-facing view of a paged pool: global-attn k/v gathered
    through the page table ``pt (B, C)`` into full-width logical rows —
    exactly the un-paged layout, so ``lm.decode_step`` runs unchanged and
    bit-identically.  All other leaves pass through."""
    def site(a, stacked):
        return dict(a, k=_gather_leaf(a["k"], pt, stacked),
                    v=_gather_leaf(a["v"], pt, stacked))
    return _map_attn_nodes(pool_caches, cfg, site)


def merge_paged(pool_caches, slot_caches, cfg, pt):
    """Fold a post-decode logical tree back into the pool: global-attn k/v
    scattered through ``pt`` (dirty-masked — sentinel entries drop, so
    clean pages are not rewritten); every other leaf — updated rings,
    recurrent states, ``len`` — is taken from ``slot_caches``."""
    ub, ut = attn_sites(cfg)
    pool = {"blocks": list(slot_caches["blocks"]),
            "tail": list(slot_caches["tail"])}
    for u in ub:
        a, pa = pool["blocks"][u]["attn"], pool_caches["blocks"][u]["attn"]
        pool["blocks"][u] = dict(pool["blocks"][u], attn=dict(
            a, k=_scatter_leaf(pa["k"], a["k"], pt, True),
            v=_scatter_leaf(pa["v"], a["v"], pt, True)))
    for t in ut:
        a, pa = pool["tail"][t]["attn"], pool_caches["tail"][t]["attn"]
        pool["tail"][t] = dict(pool["tail"][t], attn=dict(
            a, k=_scatter_leaf(pa["k"], a["k"], pt, False),
            v=_scatter_leaf(pa["v"], a["v"], pt, False)))
    return pool


def seat_caches(pool_caches, new_caches, cfg, idx, pt):
    """Check ``k`` sessions' slot-form caches into the pool: global-attn
    k/v page-chunked and scattered through ``pt (k, C')`` (sentinel-padded
    past each session's grant), every other leaf written at rows ``idx``
    (blocks batch axis 1, tail axis 0).  Serves both admission (``C' = C``
    prefill rows) and restore (``C' = n_live`` saved sub-pages)."""
    ub, ut = attn_sites(cfg)

    def wr_b(p, n):
        return p.at[:, idx].set(n.astype(p.dtype))

    def wr_t(p, n):
        return p.at[idx].set(n.astype(p.dtype))

    def node_out(pnode, nnode, u_attn, wr, stacked):
        if not u_attn:
            return jax.tree.map(wr, pnode, nnode)
        out = {}
        for kk, vv in pnode.items():
            if kk == "attn":
                na = nnode["attn"]
                out[kk] = dict(
                    vv, k=_scatter_leaf(vv["k"], na["k"], pt, stacked),
                    v=_scatter_leaf(vv["v"], na["v"], pt, stacked),
                    len=wr(vv["len"], na["len"]))
            else:
                out[kk] = jax.tree.map(wr, vv, nnode[kk])
        return out

    return {
        "blocks": [node_out(p, n, u in ub, wr_b, True) for u, (p, n)
                   in enumerate(zip(pool_caches["blocks"],
                                    new_caches["blocks"]))],
        "tail": [node_out(p, n, t in ut, wr_t, False) for t, (p, n)
                 in enumerate(zip(pool_caches["tail"],
                                  new_caches["tail"]))],
    }


def lift_slot(pool_caches, cfg, slot: int, pt1):
    """One session's park image out of the pool: global-attn k/v gathered
    at ``pt1 (1, n_live)`` — ONLY its live sub-pages travel — flattened to
    a logical ``n_live * page_size`` row; every other leaf sliced at
    ``slot``.  The restore path re-seats the image via
    :func:`seat_caches`."""
    ub, ut = attn_sites(cfg)

    def node_out(node, u_attn, stacked):
        sl = (lambda p: p[:, slot]) if stacked else (lambda p: p[slot])
        if not u_attn:
            return jax.tree.map(sl, node)
        out = {}
        for kk, vv in node.items():
            if kk == "attn":
                if stacked:
                    k = _gather_leaf(vv["k"], pt1, True)[:, 0]
                    v = _gather_leaf(vv["v"], pt1, True)[:, 0]
                else:
                    k = _gather_leaf(vv["k"], pt1, False)[0]
                    v = _gather_leaf(vv["v"], pt1, False)[0]
                out[kk] = dict(vv, k=k, v=v, len=sl(vv["len"]))
            else:
                out[kk] = jax.tree.map(sl, vv)
        return out

    return {
        "blocks": [node_out(n, u in ub, True)
                   for u, n in enumerate(pool_caches["blocks"])],
        "tail": [node_out(n, t in ut, False)
                 for t, n in enumerate(pool_caches["tail"])],
    }


def compact_slots(k: jax.Array, v: jax.Array, keep: jax.Array):
    """Remove evicted slots (keep=False) and pack survivors to the front —
    stable compaction (paper §4.2) along the slot axis.

    k, v: (B, KVH, S, dh); keep: (B, S) bool.  Returns (k, v, new_len (B,)).
    Used by H2O-style importance eviction: slots below the attention-mass
    threshold (content-comparable compare) are dropped in place.
    """
    b, kvh, s, dh = k.shape

    def one(kb, vb, keepb):                       # (KVH,S,dh),(KVH,S,dh),(S,)
        order = jnp.argsort(~keepb, stable=True)  # kept slots first
        return kb[:, order], vb[:, order]

    ks, vs = jax.vmap(one)(k, v, keep)
    new_len = jnp.sum(keep.astype(jnp.int32), axis=-1)
    return ks, vs, new_len


def splice_prefix(k: jax.Array, v: jax.Array, pk: jax.Array, pv: jax.Array,
                  used_len):
    """Prefix-cache splice: insert a cached prefix (pk, pv) before the
    current content — a content-movable range insert on the slot axis."""
    plen = pk.shape[2]
    s = k.shape[2]

    def ins(x, px):
        def per_col(col, pcol):                   # (S,) slot column
            # reference backend: under vmap+jit this fuses into one XLA
            # roll+select; auto-dispatch could pick a per-column Pallas
            # kernel launch on TPU, which would be wrong here
            return CPMArray(col, jnp.asarray(used_len, jnp.int32),
                            backend="reference").insert(0, pcol).data

        def per_row(row, prow):                   # row (S, dh)
            return jax.vmap(per_col, in_axes=(-1, -1), out_axes=-1)(row, prow)
        return jax.vmap(jax.vmap(per_row))(x, px)

    return ins(k, pk), ins(v, pv), used_len + plen


def evict_by_score(k, v, scores, keep_count: int):
    """Importance-based eviction (H2O-style): keep the ``keep_count`` slots
    with highest attention mass.  Threshold from the content-comparable
    bisection; compaction via content-movable packing."""
    from repro.cpm.reference import comparable
    keep = comparable.topk_mask(scores, keep_count)   # (B, S)
    return compact_slots(k, v, keep)
