"""KV-cache management as content-movable memory (paper §4).

The cache lives on-device; every management op is a constant number of
concurrent vector ops executed where the data is stored — never a host
round-trip over the bus.  This is the paper's thesis applied to serving:

  * sliding-window eviction  = ring overwrite (O(1), `attention_step`)
  * speculative rollback     = range delete (`truncate`)
  * hole compaction          = stable compaction (`compact_slots`)
  * prefix-cache splice      = range insert (`splice_prefix`)

All ops treat the slot axis (-2 of (B, KVH, S, dh)) as the PE address axis.
The insert/truncate paths run through :class:`repro.cpm.CPMArray` — the
cache is literally a CPM device whose ``used_len`` is the `len` leaf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cpm import CPMArray


def _map_kv(cache_tree, fn):
    """Apply fn(k_or_v, leaf_len_ctx) to every attn k/v leaf in a cache tree."""
    def walk(node):
        if isinstance(node, dict):
            if "k" in node and "v" in node and "len" in node:
                return dict(node, k=fn(node["k"]), v=fn(node["v"]))
            return {kk: walk(vv) for kk, vv in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(x) for x in node]
            return type(node)(t)
        return node
    return walk(cache_tree)


def truncate(caches, new_len):
    """Speculative-decode rollback: drop cache entries at slots >= new_len.

    A range delete in content-movable terms; entries need not be zeroed
    (the `len` mask excludes them) — we update lengths only, O(1).

    ``new_len`` may be a scalar or a per-row ``(B,)`` vector: after batched
    speculative decoding each row accepts a different draft prefix, so each
    row rolls back to its own length.  ``len`` leaves broadcast against it
    (scalar, ``(B,)``, or rep-stacked ``(R, B)`` all work).

    Cross-attention caches (``cross_kv``) hold *encoder* content — their
    length is the encoder sequence, not a decoder position — so they are
    never truncated.
    """
    new_len = jnp.asarray(new_len, jnp.int32)

    def walk(node):
        if isinstance(node, dict):
            if "len" in node and "k" in node:
                # CPMArray.truncate semantics on the slot axis: lengths only,
                # data stays put (the used-region mask excludes it)
                return dict(node, len=jnp.minimum(node["len"], new_len))
            return {kk: vv if kk == "cross_kv" else walk(vv)
                    for kk, vv in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)([walk(x) for x in node])
        return node
    return walk(caches)


def broadcast_lens(caches, batch: int):
    """Give every ``len`` leaf a trailing per-row ``(batch,)`` axis.

    Prefill produces scalar lengths (all rows equal).  The batched engine
    needs per-row lengths — rows diverge after partial draft acceptance —
    and shape-stable scan carries (``attention_step`` returns ``pos + 1``
    which is ``(B,)`` under per-row decode).  A scalar leaf becomes
    ``(B,)``, a rep-stacked ``(R,)`` leaf becomes ``(R, B)``.

    Idempotent: a leaf that already carries the batch axis is left
    untouched, so a second call cannot silently stack another batch axis
    onto every length (scalar -> ``(B,)`` -> ``(B, B)``).  The
    discriminator is the sibling data leaf in the same cache node
    (attention ``k`` or recurrent ``C``), which always has exactly three
    trailing content dims — a broadcast length has ``sib.ndim - 3`` dims,
    a fresh one ``sib.ndim - 4`` — so even a rep-stacked ``(R,)`` leaf
    with ``R == batch`` is classified correctly.  Nodes without such a
    sibling fall back to the trailing-axis-equals-``batch`` test.
    """
    def walk(node):
        if isinstance(node, dict):
            out = {}
            sib = node.get("k", node.get("C"))
            for kk, vv in node.items():
                if kk == "len":
                    lv = jnp.asarray(vv, jnp.int32)
                    if sib is not None:
                        done = lv.ndim == jnp.ndim(sib) - 3
                    else:
                        done = lv.ndim >= 1 and lv.shape[-1] == batch
                    out[kk] = lv if done else jnp.broadcast_to(
                        lv[..., None], lv.shape + (batch,))
                else:
                    out[kk] = walk(vv)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)([walk(x) for x in node])
        return node
    return walk(caches)


def compact_slots(k: jax.Array, v: jax.Array, keep: jax.Array):
    """Remove evicted slots (keep=False) and pack survivors to the front —
    stable compaction (paper §4.2) along the slot axis.

    k, v: (B, KVH, S, dh); keep: (B, S) bool.  Returns (k, v, new_len (B,)).
    Used by H2O-style importance eviction: slots below the attention-mass
    threshold (content-comparable compare) are dropped in place.
    """
    b, kvh, s, dh = k.shape

    def one(kb, vb, keepb):                       # (KVH,S,dh),(KVH,S,dh),(S,)
        order = jnp.argsort(~keepb, stable=True)  # kept slots first
        return kb[:, order], vb[:, order]

    ks, vs = jax.vmap(one)(k, v, keep)
    new_len = jnp.sum(keep.astype(jnp.int32), axis=-1)
    return ks, vs, new_len


def splice_prefix(k: jax.Array, v: jax.Array, pk: jax.Array, pv: jax.Array,
                  used_len):
    """Prefix-cache splice: insert a cached prefix (pk, pv) before the
    current content — a content-movable range insert on the slot axis."""
    plen = pk.shape[2]
    s = k.shape[2]

    def ins(x, px):
        def per_col(col, pcol):                   # (S,) slot column
            # reference backend: under vmap+jit this fuses into one XLA
            # roll+select; auto-dispatch could pick a per-column Pallas
            # kernel launch on TPU, which would be wrong here
            return CPMArray(col, jnp.asarray(used_len, jnp.int32),
                            backend="reference").insert(0, pcol).data

        def per_row(row, prow):                   # row (S, dh)
            return jax.vmap(per_col, in_axes=(-1, -1), out_axes=-1)(row, prow)
        return jax.vmap(jax.vmap(per_row))(x, px)

    return ins(k, pk), ins(v, pv), used_len + plen


def evict_by_score(k, v, scores, keep_count: int):
    """Importance-based eviction (H2O-style): keep the ``keep_count`` slots
    with highest attention mass.  Threshold from the content-comparable
    bisection; compaction via content-movable packing."""
    from repro.cpm.reference import comparable
    keep = comparable.topk_mask(scores, keep_count)   # (B, S)
    return compact_slots(k, v, keep)
