"""Sampling via content-comparable memory primitives.

top-k / top-p cutoffs are threshold problems: every logit PE compares itself
against a broadcast threshold concurrently (~1 cycle) instead of a full
sort.  The threshold itself comes from the §6.3 histogram / bisection
(``quantile_threshold``) — O(iters) compare+count steps, independent of
vocab size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cpm.reference import comparable


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def top_k_mask(logits: jax.Array, k: int) -> jax.Array:
    return comparable.topk_mask(logits, k)


def top_p_mask(probs: jax.Array, p: float, iters: int = 20) -> jax.Array:
    """Smallest prob threshold t with sum(probs[probs >= t]) >= p, by
    bisection on t — each iteration one concurrent compare + masked sum."""
    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) / 2
        mass = jnp.sum(jnp.where(probs >= mid[..., None], probs, 0.0), -1)
        ok = mass >= p                       # threshold can rise
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    b = probs.shape[:-1]
    lo, hi = jnp.zeros(b), jnp.ones(b)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return probs >= lo[..., None]


def sample(logits: jax.Array, rng, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """Batched token sampling with CPM-style truncation masks."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    logits = logits / temperature
    if top_k:
        logits = jnp.where(top_k_mask(logits, top_k), logits, -jnp.inf)
    if top_p:
        probs = jax.nn.softmax(logits, -1)
        logits = jnp.where(top_p_mask(probs, top_p), logits, -jnp.inf)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def sample_rows(logits: jax.Array, rng, temperature: jax.Array,
                top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-row sampling for pooled decode: each row of ``logits`` (B, V)
    carries its *own* ``temperature`` / ``top_k`` / ``top_p`` — (B,)
    vectors realized from per-request GenConfigs by the serving gateway.

    Rows with ``temperature <= 0`` take the greedy argmax, bit-identical
    to :func:`greedy`, so a greedy session pooled next to sampled
    neighbours keeps its solo token-identity.  ``top_k <= 0`` /
    ``top_p <= 0`` disable that truncation for the row.  The per-row
    top-k cutoff is the row's k-th largest scaled logit (a sort-based
    threshold — ``comparable.topk_mask`` needs a static k); top-p reuses
    the bisection mask, which already batches over per-row ``p``.
    """
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    t = jnp.where(temperature > 0, temperature, 1.0).astype(jnp.float32)
    x = logits / t[:, None]
    k = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v).astype(jnp.int32)
    kth = jnp.take_along_axis(jnp.sort(x, axis=-1)[:, ::-1],
                              k[:, None] - 1, axis=-1)
    x = jnp.where(x >= kth, x, -jnp.inf)
    p = jnp.where(top_p > 0, top_p, 1.0).astype(jnp.float32)
    x = jnp.where(top_p_mask(jax.nn.softmax(x, -1), p), x, -jnp.inf)
    sampled = jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy(logits))
