"""Composable model layers (pure-functional: init_* builds param dicts,
apply_* consumes them).

Mixers: GQA attention (global / local-window / cross), RG-LRU (Griffin),
mLSTM (chunked-parallel matrix memory), sLSTM (stabilized scalar memory).
FFNs: SwiGLU / GELU / ReLU dense, and MoE with CPM comparable-memory top-k
routing (the paper's technique as a first-class feature).

Every mixer exposes three modes:
  fwd(x)                  — full-sequence training/prefill forward
  fwd(x) -> (y, cache)    — prefill returning a decode cache
  step(x_t, cache) -> (y_t, cache)  — single-token decode
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.cpm.reference import comparable

Params = dict
COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + 3-axis M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dh: int, theta: float) -> tuple:
    """positions (..., S) -> cos/sin (..., S, dh//2)."""
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections=None) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) or (3, B, S) for M-RoPE."""
    dh = x.shape[-1]
    if mrope_sections is None:
        cos, sin = _rope_angles(positions, dh, theta)    # (B, S, dh/2)
    else:
        cos3, sin3 = _rope_angles(positions, dh, theta)  # (3, B, S, dh/2)
        parts_c, parts_s = [], []
        off = 0
        for i, sec in enumerate(mrope_sections):
            parts_c.append(cos3[i, ..., off:off + sec])
            parts_s.append(sin3[i, ..., off:off + sec])
            off += sec
        cos = jnp.concatenate(parts_c, -1)
        sin = jnp.concatenate(parts_s, -1)
    # angles in f32, rotation applied in the stream dtype: keeps the
    # x-sized rotated tensor (a sharding-boundary crosser) narrow
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; global causal / local window / bidirectional / cross)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False) -> Params:
    d, dh, h, kvh = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * dh)),
        "wk": _dense_init(ks[1], (d, kvh * dh)),
        "wv": _dense_init(ks[2], (d, kvh * dh)),
        "wo": _dense_init(ks[3], (h * dh, d), scale=1.0 / math.sqrt(h * dh)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((kvh * dh,), jnp.float32)
        p["bv"] = jnp.zeros((kvh * dh,), jnp.float32)
    return p


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                 kv_input: jax.Array | None = None):
    b, s, _ = x.shape
    dh, h, kvh = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    kv_x = x if kv_input is None else kv_input
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = kv_x @ p["wk"].astype(dt)
    v = kv_x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, kv_x.shape[1], kvh, dh)
    v = v.reshape(b, kv_x.shape[1], kvh, dh)
    return q, k, v


def attention_fwd(p: Params, x: jax.Array, cfg: ModelConfig, positions,
                  *, causal=True, window=None, kv_input=None,
                  kv_positions=None, rope=True, with_cache=False):
    """Full-sequence attention.  Returns y or (y, cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, kv_input)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        kpos = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kpos, cfg.rope_theta, cfg.mrope_sections)
    q = shard(q.transpose(0, 2, 1, 3), "bhsd")          # (B, H, S, dh)
    k = shard(k.transpose(0, 2, 1, 3), "bhsd")
    v = shard(v.transpose(0, 2, 1, 3), "bhsd")
    o = ops.attention(q, k, v, causal=causal, window=window)
    o = shard(o, "bhsd").transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.dh)
    y = shard(o @ p["wo"].astype(x.dtype), "btd")
    if not with_cache:
        return y
    cache = {"k": k, "v": v, "len": jnp.asarray(s, jnp.int32)}
    return y, cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=COMPUTE_DTYPE, window: int | None = None) -> Params:
    """Decode cache.  Local-window layers keep a ring buffer of `window`
    slots — sliding-window eviction is the paper's content-movable memory:
    the oldest entry is overwritten in place, O(1), where the cache lives."""
    slots = min(window, max_len) if window else max_len
    kvh, dh = cfg.n_kv_heads, cfg.dh
    return {
        "k": jnp.zeros((batch, kvh, slots, dh), dtype),
        "v": jnp.zeros((batch, kvh, slots, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def attention_step(p: Params, x_t: jax.Array, cache: Params, cfg: ModelConfig,
                   pos, *, window=None, cross_kv=None):
    """One-token decode.  x_t: (B, 1, d); pos: scalar int32 current position,
    or (B,) int32 per-row positions (rows diverge after partial draft
    acceptance in batched speculative decoding)."""
    b = x_t.shape[0]
    dh, h, kvh = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    if cross_kv is not None:
        q = (x_t @ p["wq"].astype(x_t.dtype))
        if "bq" in p:
            q = q + p["bq"].astype(x_t.dtype)
        q = q.reshape(b, 1, h, dh).transpose(0, 2, 1, 3)
        o = ops.decode_attention(q, cross_kv["k"], cross_kv["v"],
                                 cache_len=cross_kv["len"])
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
        return shard(o @ p["wo"].astype(x_t.dtype), "btd"), cache

    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1                              # (B,) positions
    posb = pos[:, None] if per_row else jnp.broadcast_to(pos, (b, 1))
    q, k, v = _project_qkv(p, x_t, cfg)
    if cfg.mrope_sections is not None:
        posb = jnp.broadcast_to(posb, (3, b, 1))
    q = apply_rope(q, posb, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, posb, cfg.rope_theta, cfg.mrope_sections)
    q = q.transpose(0, 2, 1, 3)                          # (B, H, 1, dh)
    k = k.transpose(0, 2, 1, 3)                          # (B, KVH, 1, dh)
    v = v.transpose(0, 2, 1, 3)
    slots = cache["k"].shape[2]
    slot = pos % slots                                   # ring-buffer write
    if per_row:
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, :, slot].set(k[:, :, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, :, slot].set(v[:, :, 0].astype(cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
    live = jnp.minimum(pos + 1, slots)
    if window is None:
        o = ops.decode_attention(q, ck, cv, cache_len=pos + 1)
    else:
        # ring buffer: all slots < live are valid (eviction already happened
        # in place — content-movable semantics); order irrelevant to softmax.
        o = ops.decode_attention(q, ck, cv, cache_len=live)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
    y = shard(o @ p["wo"].astype(x_t.dtype), "btd")
    return y, {"k": ck, "v": cv, "len": pos + 1}


# ---------------------------------------------------------------------------
# dense FFNs
# ---------------------------------------------------------------------------

def init_ffn(cfg: ModelConfig, key) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn == "swiglu":
        return {"w_gate": _dense_init(ks[0], (d, f)),
                "w_in": _dense_init(ks[1], (d, f)),
                "w_out": _dense_init(ks[2], (f, d))}
    return {"w_in": _dense_init(ks[0], (d, f)),
            "w_out": _dense_init(ks[1], (f, d))}


def apply_ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
    else:
        act = jax.nn.gelu if cfg.ffn == "gelu" else jax.nn.relu
        h = act(x @ p["w_in"].astype(dt))
    h = shard(h, "btf")
    return shard(h @ p["w_out"].astype(dt), "btd")


# ---------------------------------------------------------------------------
# MoE with CPM comparable-memory routing
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), scale=0.02),
        "expert_gate": _dense_init(ks[1], (e, d, f)),
        "expert_in": _dense_init(ks[2], (e, d, f)),
        "expert_out": _dense_init(ks[3], (e, f, d)),
    }


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig):
    """Top-k capacity routing.

    Routing mask via ``repro.cpm.reference.comparable.topk_mask`` — the paper's
    content-comparable memory: every token PE compares its expert scores
    against the broadcast k-th value concurrently (~1 cycle), replacing a
    serial arg-top-k.  Load statistics come from Rule-6 parallel counting.
    Dispatch/combine are scatter/gather so the expert dimension (sharded
    over "model" = expert parallelism) moves tokens with all-to-alls, not
    O(E) dense compute.

    Returns (y, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    t = b * s
    dt = x.dtype
    xt = x.reshape(t, d)
    scores = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1)              # (T, E)

    mask = comparable.topk_mask(probs, k)                # CPM routing (T, E)

    # aux load-balance loss (Rule-6 parallel counter per expert)
    load = mask.astype(jnp.float32).mean(0)              # fraction routed
    importance = probs.mean(0)
    aux = cfg.moe.router_aux_weight * e * jnp.sum(load * importance)

    # slot-major routing: (T, k) expert ids, highest-prob first.
    # stop_gradient: routing indices carry no tangent (and this JAX build's
    # multi-operand sort JVP needs batched gathers it does not support).
    eidx = jnp.argsort(jnp.where(mask, -jax.lax.stop_gradient(probs), jnp.inf),
                       axis=-1)[:, :k]
    # NOTE: one-hot contractions instead of take_along_axis — this JAX build
    # (Trainium-modified) lacks operand_batching_dims on Gather/Scatter
    # dimension numbers, which batched take_along_axis grads require.
    ohk = jax.nn.one_hot(eidx, e, dtype=probs.dtype)     # (T, k, E)
    gates_k = jnp.einsum("tke,te->tk", ohk, probs)
    gates_k = gates_k / jnp.maximum(gates_k.sum(-1, keepdims=True), 1e-9)

    cap = max(int(cfg.moe.capacity_factor * t * k / e), 4)
    # queue position of each (token, slot) inside its expert (token order).
    # log-depth associative scan (the paper's §8 super-connectivity applied
    # to the prefix sum): jnp.cumsum would lower to a reduce-window whose
    # cost is O(T^2) in both the XLA cost model and naive lowerings.
    oh = ohk.reshape(t * k, e).astype(jnp.int32)
    pos_flat = jax.lax.associative_scan(jnp.add, oh, axis=0) - 1
    pos = jnp.sum(pos_flat * oh, axis=-1).reshape(t, k)
    keep = pos < cap                                     # overflow -> dropped

    # scatter-dispatch (E sharded over "model" => all-to-all movement)
    vals = jnp.where(keep[..., None], xt[:, None, :], 0).astype(dt)  # (T,k,d)
    sc_e = jnp.where(keep, eidx, e - 1)
    sc_c = jnp.where(keep, pos, cap - 1)
    expert_x = jnp.zeros((e, cap, d), dt).at[sc_e, sc_c].add(vals)
    expert_x = shard(expert_x, "ecd")

    hg = jnp.einsum("ecd,edf->ecf", expert_x, p["expert_gate"].astype(dt))
    hi = jnp.einsum("ecd,edf->ecf", expert_x, p["expert_in"].astype(dt))
    h = shard(jax.nn.silu(hg) * hi, "ecf")
    eo = jnp.einsum("ecf,efd->ecd", h, p["expert_out"].astype(dt))
    eo = shard(eo, "ecd")

    # gather-combine weighted by gates
    gathered = eo[sc_e, sc_c]                            # (T, k, d)
    w = jnp.where(keep, gates_k, 0.0).astype(dt)
    out = jnp.einsum("tkd,tk->td", gathered, w)
    return shard(out.reshape(b, s, d), "btd"), aux


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def init_rglru(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    # a_param initialized so a = sigmoid(a_param) in [0.9, 0.999]
    lo, hi = 0.9, 0.999
    u = jax.random.uniform(ks[4], (w,), jnp.float32, lo, hi)
    return {
        "wx": _dense_init(ks[0], (d, w)),                # branch input proj
        "wg": _dense_init(ks[1], (d, w)),                # gelu gate proj
        "wy": _dense_init(ks[2], (w, d)),
        "conv_w": _dense_init(ks[3], (cfg.conv_width, w), scale=0.1),
        "a_param": jnp.log(u / (1 - u)),
        "w_input_gate": _dense_init(ks[5], (w, w), scale=0.02) if False else
            jnp.zeros((2, w), jnp.float32),              # [input gate, rec gate] diag
    }


_RGLRU_C = 8.0


def _rglru_scan(x: jax.Array, a_param, gate_x, rec_x, h0=None):
    """h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t)   via associative scan.

    The log-depth associative scan is the paper's §8 super-connectivity
    applied along the sequence: neighbor links at strides 1,2,4,…
    """
    log_a = -_RGLRU_C * jax.nn.softplus(a_param) * jax.nn.sigmoid(rec_x)
    a = jnp.exp(log_a)
    gated = x * jax.nn.sigmoid(gate_x)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_fwd(p: Params, x: jax.Array, cfg: ModelConfig, with_cache=False):
    b, s, d = x.shape
    dt = x.dtype
    w = cfg.rnn_width or d
    branch = (x @ p["wx"].astype(dt)).astype(jnp.float32)       # (B,S,W)
    gate = jax.nn.gelu((x @ p["wg"].astype(dt)).astype(jnp.float32))
    # short depthwise causal conv (Griffin's temporal conv, width 4)
    conv = jnp.zeros_like(branch)
    for i in range(cfg.conv_width):
        shifted = jnp.pad(branch, ((0, 0), (i, 0), (0, 0)))[:, :s]
        conv = conv + shifted * p["conv_w"][i]
    ig = conv * jax.nn.sigmoid(p["w_input_gate"][0])
    rg = conv * jax.nn.sigmoid(p["w_input_gate"][1])
    h = _rglru_scan(conv, p["a_param"], ig, rg)
    y = (h.astype(dt) * gate.astype(dt)) @ p["wy"].astype(dt)
    y = shard(y, "btd")
    if not with_cache:
        return y
    cw = cfg.conv_width
    if s >= cw - 1:
        buf = branch[:, s - (cw - 1):]
    else:
        buf = jnp.pad(branch, ((0, 0), (cw - 1 - s, 0), (0, 0)))
    return y, {"h": h[:, -1].astype(jnp.float32), "conv_buf": buf}


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Params:
    w = cfg.rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv_buf": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32)}


def rglru_step(p: Params, x_t: jax.Array, cache: Params, cfg: ModelConfig):
    b = x_t.shape[0]
    dt = x_t.dtype
    branch = (x_t[:, 0] @ p["wx"].astype(dt)).astype(jnp.float32)  # (B,W)
    gate = jax.nn.gelu((x_t[:, 0] @ p["wg"].astype(dt)).astype(jnp.float32))
    hist = jnp.concatenate([cache["conv_buf"], branch[:, None]], axis=1)
    # conv_w[i] multiplies the value i steps in the past; hist is oldest-first
    conv = jnp.einsum("bcw,cw->bw", hist[:, ::-1], p["conv_w"])
    ig = conv * jax.nn.sigmoid(p["w_input_gate"][0])
    rg = conv * jax.nn.sigmoid(p["w_input_gate"][1])
    log_a = -_RGLRU_C * jax.nn.softplus(p["a_param"]) * jax.nn.sigmoid(rg)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (conv * jax.nn.sigmoid(ig))
    h = a * cache["h"] + bterm
    y = ((h * gate).astype(dt) @ p["wy"].astype(dt))[:, None]
    return shard(y, "btd"), {"h": h, "conv_buf": hist[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunked-parallel training, O(1) decode
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    up = 2 * d
    h = cfg.n_heads
    dh = up // h
    ks = jax.random.split(key, 7)
    return {
        "w_up": _dense_init(ks[0], (d, up)),             # pre-up projection
        "w_up_gate": _dense_init(ks[1], (d, up)),
        # head-block-diagonal q/k/v (xLSTM's per-head projections)
        "wq": _dense_init(ks[2], (h, dh, dh), scale=1 / math.sqrt(dh)),
        "wk": _dense_init(ks[3], (h, dh, dh), scale=1 / math.sqrt(dh)),
        "wv": _dense_init(ks[4], (h, dh, dh), scale=1 / math.sqrt(dh)),
        "w_if": _dense_init(ks[5], (up, 2 * h), scale=0.02),  # input/forget gates
        "w_down": _dense_init(ks[6], (up, d)),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk: int):
    """Chunkwise-parallel mLSTM.  q,k,v: (B,H,S,dh); gates: (B,H,S) logs <= 0.

    Hardware adaptation (DESIGN.md): sigmoid input gate (log_i <= 0) keeps
    every decay factor <= 1, so the chunkwise form is stable in fp32 without
    the m-stabilizer state.
    """
    b, h, s, dh = q.shape
    assert s % chunk == 0
    n = s // chunk
    q = q.reshape(b, h, n, chunk, dh)
    k = k.reshape(b, h, n, chunk, dh)
    v = v.reshape(b, h, n, chunk, dh)
    log_f = log_f.reshape(b, h, n, chunk)
    log_i = log_i.reshape(b, h, n, chunk)
    cum_f = jnp.cumsum(log_f, axis=-1)                   # (B,H,N,C)
    total_f = cum_f[..., -1:]

    # intra-chunk decay matrix D[i,j] = exp(cum_f_i - cum_f_j + log_i_j), j<=i
    di = cum_f[..., :, None] - cum_f[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(mask, jnp.exp(di), 0.0)

    # inter-chunk state: C_n = exp(total_f) C_{n-1} + sum_j exp(total_f - cum_f_j + log_i_j) k_j v_j^T
    wk = jnp.exp(total_f - cum_f + log_i)[..., None] * k  # (B,H,N,C,dh)
    dC = jnp.einsum("bhncd,bhnce->bhnde", wk, v)          # (B,H,N,dh,dh)
    dnorm = jnp.sum(wk, axis=-2)                          # (B,H,N,dh)
    decay = jnp.exp(total_f[..., 0])                      # (B,H,N)

    def combine(c1, c2):
        a1, C1, n1 = c1
        a2, C2, n2 = c2
        return a1 * a2, C1 * a2[..., None, None] + C2, n1 * a2[..., None] + n2

    _, Ccum, ncum = jax.lax.associative_scan(
        combine, (decay, dC, dnorm), axis=2)
    # state *before* each chunk
    Cprev = jnp.concatenate([jnp.zeros_like(Ccum[:, :, :1]), Ccum[:, :, :-1]], 2)
    nprev = jnp.concatenate([jnp.zeros_like(ncum[:, :, :1]), ncum[:, :, :-1]], 2)

    qs = q * jnp.exp(cum_f)[..., None]
    inter = jnp.einsum("bhncd,bhnde->bhnce", qs, Cprev)
    inter_n = jnp.einsum("bhncd,bhnd->bhnc", qs, nprev)
    intra = jnp.einsum("bhncd,bhnjd->bhncj", q, k) * dmat
    out = inter + jnp.einsum("bhncj,bhnjd->bhncd", intra, v)
    norm = inter_n + jnp.sum(intra, -1)
    out = out / jnp.maximum(jnp.abs(norm), 1.0)[..., None]
    final_state = (Ccum[:, :, -1], ncum[:, :, -1])
    return out.reshape(b, h, s, dh), final_state


def mlstm_fwd(p: Params, x: jax.Array, cfg: ModelConfig, with_cache=False,
              chunk: int = 256):
    b, s, d = x.shape
    dt = x.dtype
    h = cfg.n_heads
    up = p["w_up"].shape[1]
    dh = up // h
    z = shard(x @ p["w_up"].astype(dt), "btf")            # (B,S,up)
    gate = jax.nn.silu(x @ p["w_up_gate"].astype(dt))
    zh = shard(z.reshape(b, s, h, dh).transpose(0, 2, 1, 3), "bhsd")
    q = shard(jnp.einsum("bhsd,hde->bhse", zh, p["wq"].astype(dt)), "bhsd")
    k = shard(jnp.einsum("bhsd,hde->bhse", zh, p["wk"].astype(dt)), "bhsd") / math.sqrt(dh)
    v = shard(jnp.einsum("bhsd,hde->bhse", zh, p["wv"].astype(dt)), "bhsd")
    gif = (z @ p["w_if"].astype(dt)).astype(jnp.float32)  # (B,S,2H)
    log_i = jax.nn.log_sigmoid(gif[..., :h]).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(gif[..., h:]).transpose(0, 2, 1)
    c = min(chunk, s)
    out, (C, nrm) = _mlstm_chunk_scan(q.astype(jnp.float32), k.astype(jnp.float32),
                                      v.astype(jnp.float32), log_f, log_i, c)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, up).astype(dt)
    y = shard((out * gate) @ p["w_down"].astype(dt), "btd")
    if not with_cache:
        return y
    return y, {"C": C, "n": nrm, "len": jnp.asarray(s, jnp.int32)}


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    up = 2 * cfg.d_model
    h = cfg.n_heads
    dh = up // h
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "len": jnp.zeros((), jnp.int32)}


def mlstm_step(p: Params, x_t: jax.Array, cache: Params, cfg: ModelConfig):
    b = x_t.shape[0]
    dt = x_t.dtype
    h = cfg.n_heads
    up = p["w_up"].shape[1]
    dh = up // h
    z = x_t[:, 0] @ p["w_up"].astype(dt)
    gate = jax.nn.silu(x_t[:, 0] @ p["w_up_gate"].astype(dt))
    zh = z.reshape(b, h, dh)
    q = jnp.einsum("bhd,hde->bhe", zh, p["wq"].astype(dt)).astype(jnp.float32)
    k = (jnp.einsum("bhd,hde->bhe", zh, p["wk"].astype(dt)) / math.sqrt(dh)).astype(jnp.float32)
    v = jnp.einsum("bhd,hde->bhe", zh, p["wv"].astype(dt)).astype(jnp.float32)
    gif = (z @ p["w_if"].astype(dt)).astype(jnp.float32)
    i_g = jnp.exp(jax.nn.log_sigmoid(gif[..., :h]))[..., None]       # (B,H,1)
    f_g = jnp.exp(jax.nn.log_sigmoid(gif[..., h:]))[..., None]
    C = f_g[..., None] * cache["C"] + i_g[..., None] * k[..., :, None] * v[..., None, :]
    nrm = f_g * cache["n"] + i_g * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, nrm)), 1.0)
    out = (num / den[..., None]).reshape(b, up).astype(dt)
    y = ((out * gate) @ p["w_down"].astype(dt))[:, None]
    return shard(y, "btd"), {"C": C, "n": nrm, "len": cache["len"] + 1}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory, stabilized exponential gating)
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "wx": _dense_init(ks[0], (d, 4 * d)),            # z, i, f, o pre-acts
        "rec_w": _dense_init(ks[1], (h, dh, 4 * dh), scale=0.02),
        "w_down": _dense_init(ks[2], (d, d)),
    }


def _slstm_cell(p, cfg, x_pre, state):
    """x_pre: (B, 4D) input pre-activations; state: (c, n, h, m) each (B,H,dh)."""
    b = x_pre.shape[0]
    hh = cfg.n_heads
    d = cfg.d_model
    dh = d // hh
    c, n, hprev, m = state
    rec = jnp.einsum("bhd,hdk->bhk", hprev, p["rec_w"].astype(hprev.dtype))
    pre = x_pre.reshape(b, hh, 4 * dh) + rec
    z = jnp.tanh(pre[..., :dh])
    i_l = pre[..., dh:2 * dh]                             # log-space input gate
    f_l = jax.nn.log_sigmoid(pre[..., 2 * dh:3 * dh])     # log forget
    o = jax.nn.sigmoid(pre[..., 3 * dh:])
    m_new = jnp.maximum(f_l + m, i_l)
    i_g = jnp.exp(i_l - m_new)
    f_g = jnp.exp(f_l + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_fwd(p: Params, x: jax.Array, cfg: ModelConfig, with_cache=False):
    b, s, d = x.shape
    dt = x.dtype
    hh = cfg.n_heads
    dh = d // hh
    x_pre = (x @ p["wx"].astype(dt)).astype(jnp.float32)   # (B,S,4D)
    rec_w = p["rec_w"].astype(jnp.float32)

    def local_scan(x_pre, rec_w):
        """Batch-local recurrence.  Run under shard_map when a mesh is
        active: the 4096-step scan must be device-local — any re-sharding
        freedom inside the loop costs one collective *per timestep*."""
        bl = x_pre.shape[0]
        init = tuple(jnp.zeros((bl, hh, dh), jnp.float32) for _ in range(3)) + (
            jnp.full((bl, hh, dh), -1e30, jnp.float32),)
        pp = {"rec_w": rec_w}

        def step(state, xp):
            new = _slstm_cell(pp, cfg, xp, state)
            return new, new[2]

        state, hs = jax.lax.scan(step, init, x_pre.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2, 3).reshape(bl, x_pre.shape[1], d), state

    from repro.distributed.sharding import current_ctx
    ctx = current_ctx()
    if ctx.mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = ctx.dp
        local_scan = shard_map(
            local_scan, mesh=ctx.mesh,
            in_specs=(P(dp, None, None), P(None, None, None)),
            out_specs=(P(dp, None, None),
                       tuple(P(dp, None, None) for _ in range(4))),
            check_rep=False)
    out, state = local_scan(x_pre, rec_w)
    y = shard(out.astype(dt) @ p["w_down"].astype(dt), "btd")
    if not with_cache:
        return y
    return y, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    hh = cfg.n_heads
    dh = cfg.d_model // hh
    z = jnp.zeros((batch, hh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, hh, dh), -1e30, jnp.float32)}


def slstm_step(p: Params, x_t: jax.Array, cache: Params, cfg: ModelConfig):
    dt = x_t.dtype
    x_pre = (x_t[:, 0] @ p["wx"].astype(dt)).astype(jnp.float32)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, cfg, x_pre, state)
    b = x_t.shape[0]
    out = h.reshape(b, -1).astype(dt)
    y = (out @ p["w_down"].astype(dt))[:, None]
    return shard(y, "btd"), {"c": c, "n": n, "h": h, "m": m}
