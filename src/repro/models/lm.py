"""Model assembly: pattern-scan decoder LMs, the enc-dec (audio) variant and
the VLM patch-merge variant, with train / prefill / decode entry points.

Layer stacking: the repeating pattern unit (e.g. (rglru, rglru, attn_local))
is scanned over its repeats with stacked params — one traced unit regardless
of depth, which keeps HLO size O(unit) for the 512-device dry-run compiles.
Remainder layers (38 = 12x3 + 2) are applied unstacked.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import compute_view, shard
from . import layers as L

Params = dict


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, kind: str, key, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg, cfg.d_model)}
    if kind in ("attn", "attn_local"):
        p["attn"] = L.init_attention(cfg, ks[0])
    elif kind == "rglru":
        p["rglru"] = L.init_rglru(cfg, ks[0])
    elif kind == "mlstm":
        p["mlstm"] = L.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["slstm"] = L.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if cross:
        p["norm_cross"] = L.init_norm(cfg, cfg.d_model)
        p["cross"] = L.init_attention(cfg, ks[2], cross=True)
    if cfg.ffn != "none" and kind not in ("mlstm", "slstm"):
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
        p["ffn"] = L.init_moe(cfg, ks[1]) if cfg.ffn == "moe" else L.init_ffn(cfg, ks[1])
    return p


def block_fwd(p: Params, x, kind: str, cfg: ModelConfig, positions, *,
              causal=True, enc_out=None, enc_positions=None, with_cache=False):
    """Full-sequence block.  Returns (x, aux, cache)."""
    p = compute_view(p, L.COMPUTE_DTYPE)      # FSDP: gather bf16 weights here
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = L.apply_norm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        win = cfg.window if kind == "attn_local" else None
        out = L.attention_fwd(p["attn"], h, cfg, positions, causal=causal,
                              window=win, with_cache=with_cache)
        if with_cache:
            out, cache = out
            cache = {"attn": cache}
    elif kind == "rglru":
        out = L.rglru_fwd(p["rglru"], h, cfg, with_cache=with_cache)
        if with_cache:
            out, c = out
            cache = {"rglru": c}
    elif kind == "mlstm":
        out = L.mlstm_fwd(p["mlstm"], h, cfg, with_cache=with_cache)
        if with_cache:
            out, c = out
            cache = {"mlstm": c}
    elif kind == "slstm":
        out = L.slstm_fwd(p["slstm"], h, cfg, with_cache=with_cache)
        if with_cache:
            out, c = out
            cache = {"slstm": c}
    x = x + out
    if "cross" in p:
        h = L.apply_norm(p["norm_cross"], x, cfg.norm_eps)
        ck, cv = _cross_kv(p["cross"], enc_out, cfg)
        out = L.attention_fwd(p["cross"], h, cfg, positions, causal=False,
                              kv_input=enc_out, kv_positions=enc_positions,
                              rope=False)
        if with_cache:
            cache["cross_kv"] = {"k": ck, "v": cv,
                                 "len": jnp.asarray(enc_out.shape[1], jnp.int32)}
        x = x + out
    if "ffn" in p:
        h = L.apply_norm(p["norm2"], x, cfg.norm_eps)
        if cfg.ffn == "moe":
            out, aux = L.apply_moe(p["ffn"], h, cfg)
        else:
            out = L.apply_ffn(p["ffn"], h, cfg)
        x = x + out
    return shard(x, "btd"), aux, cache


def _cross_kv(p: Params, enc_out, cfg: ModelConfig):
    b, ts, _ = enc_out.shape
    kvh, dh = cfg.n_kv_heads, cfg.dh
    dt = enc_out.dtype
    k = (enc_out @ p["wk"].astype(dt)).reshape(b, ts, kvh, dh).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"].astype(dt)).reshape(b, ts, kvh, dh).transpose(0, 2, 1, 3)
    return k, v


def block_step(p: Params, x_t, cache: Params, kind: str, cfg: ModelConfig, pos):
    """One-token decode.  Returns (x_t, cache)."""
    p = compute_view(p, L.COMPUTE_DTYPE)
    h = L.apply_norm(p["norm1"], x_t, cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        win = cfg.window if kind == "attn_local" else None
        out, c = L.attention_step(p["attn"], h, cache["attn"], cfg, pos, window=win)
        cache = dict(cache, attn=c)
    elif kind == "rglru":
        out, c = L.rglru_step(p["rglru"], h, cache["rglru"], cfg)
        cache = dict(cache, rglru=c)
    elif kind == "mlstm":
        out, c = L.mlstm_step(p["mlstm"], h, cache["mlstm"], cfg)
        cache = dict(cache, mlstm=c)
    elif kind == "slstm":
        out, c = L.slstm_step(p["slstm"], h, cache["slstm"], cfg)
        cache = dict(cache, slstm=c)
    x_t = x_t + out
    if "cross" in p:
        h = L.apply_norm(p["norm_cross"], x_t, cfg.norm_eps)
        out, _ = L.attention_step(p["cross"], h, {}, cfg, pos,
                                  cross_kv=cache["cross_kv"])
        x_t = x_t + out
    if "ffn" in p:
        h = L.apply_norm(p["norm2"], x_t, cfg.norm_eps)
        if cfg.ffn == "moe":
            out, _ = L.apply_moe(p["ffn"], h, cfg)
        else:
            out = L.apply_ffn(p["ffn"], h, cfg)
        x_t = x_t + out
    return x_t, cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     cross_len: int = 0, dtype=L.COMPUTE_DTYPE) -> Params:
    c: Params = {}
    if kind in ("attn", "attn_local"):
        win = cfg.window if kind == "attn_local" else None
        c["attn"] = L.init_attn_cache(cfg, batch, max_len, dtype, window=win)
    elif kind == "rglru":
        c["rglru"] = L.init_rglru_cache(cfg, batch)
    elif kind == "mlstm":
        c["mlstm"] = L.init_mlstm_cache(cfg, batch)
    elif kind == "slstm":
        c["slstm"] = L.init_slstm_cache(cfg, batch)
    if cross_len:
        c["cross_kv"] = {"k": jnp.zeros((batch, cfg.n_kv_heads, cross_len, cfg.dh), dtype),
                         "v": jnp.zeros((batch, cfg.n_kv_heads, cross_len, cfg.dh), dtype),
                         "len": jnp.asarray(cross_len, jnp.int32)}
    return c


# ---------------------------------------------------------------------------
# pattern stacking helpers
# ---------------------------------------------------------------------------

def _layout(cfg: ModelConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    """(unit, n_repeats, tail_kinds)."""
    kinds = cfg.layer_kinds()
    unit = tuple(cfg.pattern)
    n_rep = len(kinds) // len(unit)
    if n_rep == 0:                     # fewer layers than one unit (smoke)
        return tuple(kinds), 1, ()
    tail = kinds[n_rep * len(unit):]
    return unit, n_rep, tail


# scan bodies with <= this many repeats unroll into straight-line HLO so the
# dry-run cost probes (1-unit vs 2-unit extrapolation) see per-layer cost
_UNROLL = 2


def _stack(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees) if len(trees) > 1 else \
        jax.tree.map(lambda x: x[None], trees[0])


def _unstack_slice(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


# ---------------------------------------------------------------------------
# top-level model
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig) -> int:
    """Embedding rows padded to 512 so the vocab axis always divides the TP
    degree (MaxText-style); padded logits are masked to -inf."""
    return -(-cfg.vocab_size // 512) * 512


def init_params(cfg: ModelConfig, key) -> Params:
    unit, n_rep, tail = _layout(cfg)
    keys = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 3)
    ki = iter(range(len(keys)))
    cross = cfg.enc_dec
    vp = padded_vocab(cfg)
    p: Params = {}
    p["emb"] = jax.random.normal(keys[next(ki)], (vp, cfg.d_model),
                                 jnp.float32) * 0.02
    if not cfg.tie_embeddings:
        p["unemb"] = jax.random.normal(keys[next(ki)], (vp, cfg.d_model),
                                       jnp.float32) * 0.02
    p["final_norm"] = L.init_norm(cfg, cfg.d_model)

    # decoder (or the only) stack
    stacked = []
    for u, kind in enumerate(unit):
        base = keys[next(ki)]
        per_rep = [init_block(cfg, kind, jax.random.fold_in(base, r), cross=cross)
                   for r in range(n_rep)]
        stacked.append(_stack(per_rep))
    p["blocks"] = stacked
    p["tail"] = [init_block(cfg, kind, keys[next(ki)], cross=cross) for kind in tail]

    if cfg.enc_dec:
        enc_blocks = [init_block(cfg, "attn", jax.random.fold_in(keys[-1], r))
                      for r in range(cfg.n_enc_layers)]
        p["encoder"] = {"blocks": _stack(enc_blocks),
                        "norm": L.init_norm(cfg, cfg.d_model)}
    return p


def _embed(params: Params, cfg: ModelConfig, tokens, batch: dict):
    emb = compute_view({"emb": params["emb"]}, L.COMPUTE_DTYPE)["emb"]
    x = emb[tokens] * math.sqrt(cfg.d_model)
    if cfg.mrope_sections is not None and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        bsz = x.shape[0]
        x = x.at[jnp.arange(bsz)[:, None], batch["patch_pos"]].set(pe)
    return shard(x, "btd")


def _mask_pad(logits, cfg: ModelConfig):
    vp = logits.shape[-1]
    if vp == cfg.vocab_size:
        return logits
    return jnp.where(jnp.arange(vp) < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


def _logits(params: Params, cfg: ModelConfig, x):
    name = "emb" if cfg.tie_embeddings else "unemb"
    w = compute_view({name: params[name]}, L.COMPUTE_DTYPE)[name]
    return _mask_pad(shard(x @ w.astype(x.dtype).T, "btv"), cfg)


def _positions(cfg: ModelConfig, batch: dict, s: int, b: int):
    if cfg.mrope_sections is not None:
        if "pos_ids" in batch:
            return batch["pos_ids"]
        return jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    return jnp.broadcast_to(jnp.arange(s)[None], (b, s))


def _run_encoder(params: Params, cfg: ModelConfig, src_embeds):
    b, ts, _ = src_embeds.shape
    x = shard(src_embeds.astype(L.COMPUTE_DTYPE), "btd")
    pos = jnp.broadcast_to(jnp.arange(ts)[None], (b, ts))

    def body(x, blk):
        x, _, _ = block_fwd(blk, x, "attn", cfg, pos, causal=False)
        return x, None

    if cfg.n_enc_layers <= _UNROLL:
        for r in range(cfg.n_enc_layers):
            x, _ = body(x, _unstack_slice(params["encoder"]["blocks"], r))
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return L.apply_norm(params["encoder"]["norm"], x, cfg.norm_eps)


def forward(params: Params, cfg: ModelConfig, batch: dict, *,
            remat: bool = True):
    """Full-sequence forward.  Returns (x_final, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, batch)
    positions = _positions(cfg, batch, s, b)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _run_encoder(params, cfg, batch["src_embeds"])
    enc_pos = None

    unit, n_rep, tail = _layout(cfg)

    def unit_body(carry, blks):
        x, aux = carry
        for u, kind in enumerate(unit):
            x, a, _ = block_fwd(blks[u], x, kind, cfg, positions,
                                enc_out=enc_out, enc_positions=enc_pos)
            aux = aux + a
        return (x, aux), None

    if remat:
        import os
        pol = os.environ.get("REPRO_REMAT_POLICY", "")
        policy = getattr(jax.checkpoint_policies, pol) if pol else None
        body = jax.checkpoint(unit_body, policy=policy)
    else:
        body = unit_body
    carry = (x, jnp.zeros((), jnp.float32))
    if n_rep <= _UNROLL:                 # cost-probe path: no while loop
        for r in range(n_rep):
            carry, _ = body(carry, _unstack_slice(params["blocks"], r))
    else:
        carry, _ = jax.lax.scan(body, carry, params["blocks"])
    x, aux = carry
    for blk, kind in zip(params["tail"], tail):
        x, a, _ = block_fwd(blk, x, kind, cfg, positions,
                            enc_out=enc_out, enc_positions=enc_pos)
        aux = aux + a
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, loss_chunk: int = 1024):
    """Next-token CE with sequence-chunked logits (never materializes
    (B, S, V) — the logit chunk is (B, C, V_shard))."""
    x, aux = forward(params, cfg, batch, remat=remat)
    tokens = batch["tokens"]
    b, s = tokens.shape
    # predict token t+1 from position t
    xs = x[:, :-1]
    labels = tokens[:, 1:]
    n = s - 1
    chunk = min(loss_chunk, n)
    while n % chunk:
        chunk -= 1
    name = "emb" if cfg.tie_embeddings else "unemb"
    w = compute_view({name: params[name]}, L.COMPUTE_DTYPE)[name]

    def ce_chunk(carry, idx):
        tot, cnt = carry
        xi = jax.lax.dynamic_slice_in_dim(xs, idx * chunk, chunk, axis=1)
        yi = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = shard(xi @ w.astype(xi.dtype).T, "btv").astype(jnp.float32)
        logits = _mask_pad(logits, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum(lse - gold)
        return (tot, cnt + gold.size), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (jnp.zeros((), jnp.float32), 0),
                                 jnp.arange(n // chunk))
    loss = tot / cnt + aux
    return loss, {"ce": tot / cnt, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, batch: dict, max_len: int = 0):
    """Full-sequence forward that also returns per-layer caches and the
    logits of the last position.  ``max_len`` reserves decode headroom:
    global-attn caches are padded to it, local-window caches become rings."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, batch)
    positions = _positions(cfg, batch, s, b)
    enc_out = _run_encoder(params, cfg, batch["src_embeds"]) if cfg.enc_dec else None

    unit, n_rep, tail = _layout(cfg)

    def unit_body(x, blks):
        caches = []
        for u, kind in enumerate(unit):
            x, _, c = block_fwd(blks[u], x, kind, cfg, positions,
                                enc_out=enc_out, with_cache=True)
            caches.append(c)
        return x, tuple(caches)

    if n_rep <= _UNROLL:
        outs = []
        for r in range(n_rep):
            x, cs = unit_body(x, _unstack_slice(params["blocks"], r))
            outs.append(cs)
        stacked_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) \
            if len(outs) > 1 else jax.tree.map(lambda y: y[None], outs[0])
    else:
        x, stacked_caches = jax.lax.scan(unit_body, x, params["blocks"])
    tail_caches = []
    for blk, kind in zip(params["tail"], tail):
        x, _, c = block_fwd(blk, x, kind, cfg, positions,
                            enc_out=enc_out, with_cache=True)
        tail_caches.append(c)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x[:, -1:])
    caches = {"blocks": list(stacked_caches), "tail": tail_caches}
    caches = _finalize_caches(cfg, caches, s, max(max_len, s))
    return logits, caches


def _finalize_caches(cfg: ModelConfig, caches, s: int, max_len: int):
    """Prefill attn caches come back prompt-length; re-lay them out for
    decode: global-attn caches padded to ``max_len`` slots, local-window
    caches to W-slot rings at slot = pos % W (CPM content-movable layout —
    eviction overwrites in place where the cache lives)."""
    unit, n_rep, tail = _layout(cfg)

    def conv(cache, kind):
        if kind not in ("attn", "attn_local") or "attn" not in cache:
            return cache
        k, v = cache["attn"]["k"], cache["attn"]["v"]
        if kind == "attn_local":
            w = min(cfg.window, max_len)
            if k.shape[2] > w:
                last = jnp.arange(s - w, s)
                ring = jnp.zeros((k.shape[0], k.shape[1], w, k.shape[3]), k.dtype)
                k = ring.at[:, :, last % w].set(k[:, :, last])
                v = ring.at[:, :, last % w].set(v[:, :, last])
            elif k.shape[2] < w:
                pad = [(0, 0), (0, 0), (0, w - k.shape[2]), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        else:
            if k.shape[2] < max_len:
                pad = [(0, 0), (0, 0), (0, max_len - k.shape[2]), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return dict(cache, attn={"k": k, "v": v, "len": cache["attn"]["len"]})

    out_blocks = []
    for u, kind in enumerate(unit):
        cu = caches["blocks"][u]
        if kind in ("attn", "attn_local"):
            cu = jax.vmap(lambda c: conv(c, kind))(cu)
        out_blocks.append(cu)
    out_tail = [conv(c, kind) for c, kind in zip(caches["tail"], tail)]
    return {"blocks": out_blocks, "tail": out_tail}


def init_caches(cfg: ModelConfig, batch: int, max_len: int, cross_len: int = 0,
                dtype=L.COMPUTE_DTYPE) -> dict:
    """Zero caches shaped for decode (the dry-run decode input)."""
    unit, n_rep, tail = _layout(cfg)
    blocks = []
    for kind in unit:
        one = init_block_cache(cfg, kind, batch, max_len, cross_len, dtype)
        blocks.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape), one))
    tails = [init_block_cache(cfg, kind, batch, max_len, cross_len, dtype)
             for kind in tail]
    return {"blocks": blocks, "tail": tails}


def decode_step(params: Params, cfg: ModelConfig, tokens_t, caches: dict, pos):
    """One decode step.  tokens_t: (B, 1); pos: scalar int32 or (B,) int32
    per-row positions.  Returns (logits (B,1,V), new caches)."""
    b = tokens_t.shape[0]
    x = _embed(params, cfg, tokens_t, {"tokens": tokens_t})
    unit, n_rep, tail = _layout(cfg)

    # caches are updated IN PLACE through a fori_loop carry (dynamic-update-
    # slice on a loop-carried buffer lowers to an in-place write) — the
    # content-movable discipline: the KV cache never leaves its storage.
    stacked = tuple(caches["blocks"])

    def layer_iter(r, carry):
        x, cs = carry
        for u, kind in enumerate(unit):
            blk = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False),
                params["blocks"][u])
            cu = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False),
                cs[u])
            x, new_cu = block_step(blk, x, cu, kind, cfg, pos)
            cs = (cs[:u]
                  + (jax.tree.map(lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                      buf, n.astype(buf.dtype), r, 0), cs[u], new_cu),)
                  + cs[u + 1:])
        return x, cs

    if n_rep <= _UNROLL:
        carry = (x, stacked)
        for r in range(n_rep):
            carry = layer_iter(r, carry)
        x, new_stacked = carry
    else:
        x, new_stacked = jax.lax.fori_loop(0, n_rep, layer_iter, (x, stacked))
    new_tail = []
    for blk, c, kind in zip(params["tail"], caches["tail"], tail):
        x, c = block_step(blk, x, c, kind, cfg, pos)
        new_tail.append(c)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, {"blocks": list(new_stacked), "tail": new_tail}


# ---------------------------------------------------------------------------
# serving: teacher-forced multi-token decode (draft verification)
# ---------------------------------------------------------------------------

def _snapshot_caches(cfg: ModelConfig, caches: dict) -> dict:
    """The per-step rollback snapshot of a cache tree: everything except
    global-attention K/V buffers (those are append-only at slot == pos and
    masked by ``len``, so they roll back with an O(1) per-row length
    truncation — ``kv_cache.truncate``) and cross-attention K/V (static
    during decode).  What remains — recurrent states (rglru/mlstm/slstm),
    local-window rings (O(window) slots by construction) and their lengths —
    must be snapshotted because in-place updates destroy history."""
    unit, n_rep, tail = _layout(cfg)

    def strip(c, kind):
        out = {kk: vv for kk, vv in c.items() if kk != "cross_kv"}
        if kind == "attn":
            out.pop("attn", None)
        return out

    return {"blocks": [strip(c, k) for c, k in zip(caches["blocks"], unit)],
            "tail": [strip(c, k) for c, k in zip(caches["tail"], tail)]}


def decode_multi(params: Params, cfg: ModelConfig, tokens, caches: dict, pos):
    """Teacher-forced decode over ``T`` tokens in ONE compiled forward.

    tokens: (B, T) int32 — token t is fed at position ``pos + t`` (per row).
    pos: (B,) int32 start positions.  Caches must have per-row ``len``
    leaves (see ``kv_cache.broadcast_lens``).

    Returns ``(logits (B, T, V), caches, snaps)``: the logits of every
    position, the caches after all T writes, and per-step rollback
    snapshots (leading axis T; see ``_snapshot_caches``) for
    ``rollback_caches`` after partial draft acceptance.

    The loop over T is a ``jax.lax.scan`` — a single XLA program with zero
    host syncs, the CPM carry-chain verification schedule: every layer's
    state advances in place where it is stored while the host never sees an
    intermediate token.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (tokens.shape[0],))

    def body(carry, tok_t):
        caches, p = carry
        logits, caches = decode_step(params, cfg, tok_t[:, None], caches, p)
        return (caches, p + 1), (logits[:, 0], _snapshot_caches(cfg, caches))

    (caches, _), (lg, snaps) = jax.lax.scan(
        body, (caches, pos), jnp.transpose(tokens).astype(jnp.int32))
    return jnp.moveaxis(lg, 0, 1), caches, snaps


def rollback_caches(cfg: ModelConfig, caches: dict, snaps: dict, idx) -> dict:
    """Roll a ``decode_multi`` result back to ``idx[b] + 1`` committed steps
    per row (idx = n_emit - 1; every row commits at least one step).

    Snapshotted leaves are gathered at the per-row step index.  Global-attn
    K/V keep their final buffers: rejected entries sit at slots past the
    accepted prefix, excluded by the subsequent per-row
    ``kv_cache.truncate`` and deterministically overwritten by later writes
    at the same positions.  Cross-attn K/V never changed.
    """
    unit, n_rep, tail = _layout(cfg)
    idx = jnp.asarray(idx, jnp.int32)

    def sel(leaf, baxis):
        # leaf: (T, ..., B, ...) with the batch axis at `baxis`
        moved = jnp.moveaxis(leaf, baxis, 0)              # (B, T, ...)
        out = jax.vmap(lambda yb, i: yb[i])(moved, idx)   # (B, ...)
        return jnp.moveaxis(out, 0, baxis - 1)

    def merge(final_c, snap_c, kind, baxis):
        out = {}
        for kk, vv in final_c.items():
            if kk == "cross_kv" or (kind == "attn" and kk == "attn"):
                out[kk] = vv
            else:
                out[kk] = jax.tree.map(lambda s: sel(s, baxis), snap_c[kk])
        return out

    return {"blocks": [merge(c, sc, k, 2) for c, sc, k in
                       zip(caches["blocks"], snaps["blocks"], unit)],
            "tail": [merge(c, sc, k, 1) for c, sc, k in
                     zip(caches["tail"], snaps["tail"], tail)]}
