from . import layers, lm
from .lm import (decode_step, forward, init_caches, init_params, loss_fn,
                 prefill)

__all__ = ["layers", "lm", "init_params", "forward", "loss_fn", "prefill",
           "decode_step", "init_caches"]
