"""Partition rules: FSDP × TP × EP × pod-DP on a ("pod","data","model") mesh.

Logical activation kinds and per-parameter specs, with divisibility-checked
fallback chains (a dim that does not divide its mesh axis falls back to the
next candidate spec, ending in replication) so every assigned architecture
shards cleanly on both the single-pod (16,16) and multi-pod (2,16,16) mesh.

Rule 4 connection: a PartitionSpec *is* the paper's general-decoder range
activation — it selects which PEs (chips) hold/compute which address range
of each tensor, in O(1) metadata.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field, replace

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ()        # ("pod","data") or ("data",)
    model_axis: str | None = None          # "model"
    fsdp: bool = True                      # ZeRO-3 param/opt-state sharding
    seq_axis: str | None = None            # sequence parallelism (perf opt)

    @property
    def dp(self):
        return self.data_axes if self.data_axes else None

    def axis_size(self, name) -> int:
        if self.mesh is None or name is None:
            return 1
        if isinstance(name, tuple):
            return int(np.prod([self.axis_size(a) for a in name]))
        return self.mesh.shape[name]


_CTX = ShardingCtx()


def set_sharding_ctx(ctx: ShardingCtx) -> None:
    global _CTX
    _CTX = ctx


def current_ctx() -> ShardingCtx:
    return _CTX


@contextlib.contextmanager
def use_sharding(ctx: ShardingCtx):
    global _CTX
    prev, _CTX = _CTX, ctx
    try:
        yield ctx
    finally:
        _CTX = prev


def make_ctx(mesh: Mesh | None, fsdp: bool = True,
             seq_shard: bool = False, pure_dp: bool = False) -> ShardingCtx:
    """``pure_dp``: re-role the "model" mesh axis as additional data
    parallelism (ZeRO-3 over all 256/512 chips, no tensor parallelism).
    For dense models at large batch this moves ~10x fewer bytes than
    16-way TP: activation all-reduces scale with tokens x d_model per
    layer, while ZeRO param gathers scale with param bytes only."""
    if mesh is None:
        return ShardingCtx()
    axes = mesh.axis_names
    if pure_dp:
        return ShardingCtx(mesh=mesh, data_axes=tuple(axes), model_axis=None,
                           fsdp=fsdp)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    model = "model" if "model" in axes else None
    return ShardingCtx(mesh=mesh, data_axes=data_axes, model_axis=model,
                       fsdp=fsdp, seq_axis=("model" if seq_shard else None))


# ---------------------------------------------------------------------------
# activation sharding
# ---------------------------------------------------------------------------

def _fits(dim: int, axis, ctx: ShardingCtx) -> bool:
    return axis is None or dim % ctx.axis_size(axis) == 0


import os

_SP = bool(int(os.environ.get("REPRO_SP", "0")))
_MOE_CAP_DP = bool(int(os.environ.get("REPRO_MOE_CAP_DP", "0")))
_EP_AXIS_DATA = bool(int(os.environ.get("REPRO_EP_DATA", "0")))    # Megatron-style sequence
                                                    # parallelism on the
                                                    # residual stream


def act_spec(kind: str, shape: tuple[int, ...] | None = None,
             ctx: ShardingCtx | None = None) -> P:
    """Activation PartitionSpec by logical kind."""
    c = ctx or _CTX
    if c.mesh is None:
        return P()
    dp, mdl = c.dp, c.model_axis
    table = {
        "btd":  P(dp, mdl if _SP else c.seq_axis, None),  # (batch, seq, d)
        "bthd": P(dp, None, mdl, None),             # (batch, seq|1, heads, dh)
        "bhsd": P(dp, mdl, None, None),             # (batch, heads, seq, dh)
        "btf":  P(dp, None, mdl),                   # (batch, seq, d_ff)
        "btv":  P(dp, None, mdl),                   # logits
        "bt":   P(dp, None),                        # token ids / labels
        "b":    P(dp),
        "ecd":  P("data" if _EP_AXIS_DATA else mdl,
                  dp if _MOE_CAP_DP else None, None),        # (experts, cap, d)
        "ecf":  P("data" if _EP_AXIS_DATA else mdl,
                  dp if _MOE_CAP_DP else None,
                  mdl if _EP_AXIS_DATA else None),           # (experts, cap, ff)
        "bte":  P(dp, None, None),                  # router scores
    }
    spec = table[kind]
    if shape is not None:
        fixed = []
        for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            fixed.append(axis if _fits(dim, axis, c) else None)
        spec = P(*fixed)
    return spec


def shard(x: jax.Array, kind: str, ctx: ShardingCtx | None = None) -> jax.Array:
    """with_sharding_constraint by logical kind; no-op without a mesh."""
    c = ctx or _CTX
    if c.mesh is None:
        return x
    spec = act_spec(kind, x.shape, c)
    return jax.lax.with_sharding_constraint(x, NamedSharding(c.mesh, spec))


# ---------------------------------------------------------------------------
# parameter partition rules
# ---------------------------------------------------------------------------

def _candidates(path: str, ndim: int, ctx: ShardingCtx) -> list[P]:
    """Ordered spec candidates for a parameter, best first."""
    dp = ctx.dp if ctx.fsdp else None
    mdl = ctx.model_axis
    name = path.split("/")[-1]

    def c(*specs):
        return [P(*s) for s in specs]

    if name in ("emb", "unemb"):                       # (vocab, d)
        return c((mdl, dp), (None, mdl), (None, dp), (None, None))
    if name in ("wq", "wk", "wv", "wkv", "w_gate", "w_in", "wx", "wg", "w_up",
                "w_z", "w_i", "w_f", "w_o_gate"):      # (d_in, big)
        return c((dp, mdl), (None, mdl), (dp, None), (None, None))
    if name in ("wo", "w_out", "w_down", "wy"):        # (big, d)
        return c((mdl, dp), (mdl, None), (None, dp), (None, None))
    if name == "router":                               # (d, E)
        return c((dp, None), (None, None))
    if name.startswith("expert"):                      # (E, d, ff) / (E, ff, d)
        if _EP_AXIS_DATA:
            return c(("data", None, mdl), ("data", None, None),
                     (None, None, None))
        return c((mdl, dp, None), (mdl, None, None), (None, None, None))
    if name == "rec_w":                                # sLSTM (H, dh, dh)
        return c((mdl, None, None), (None, None, None))
    if name in ("conv_w",):                            # (width, channels)
        return c((None, mdl), (None, None))
    # norms, biases, gate vectors: shard last dim over model if it fits
    if ndim == 1:
        return c((mdl,), (None,))
    return c(*[(None,) * ndim])


def param_spec(path: str, shape: tuple[int, ...],
               ctx: ShardingCtx | None = None) -> P:
    c = ctx or _CTX
    if c.mesh is None:
        return P()
    ndim = len(shape)
    # stacked-layer leading axes (scan stacking) are never sharded
    base_ndim = ndim
    for cand in _candidates(path, ndim, c):
        cand_full = (None,) * (ndim - len(cand)) + tuple(cand)
        if all(_fits(d, a, c) for d, a in zip(shape, cand_full)):
            return P(*cand_full)
    return P(*([None] * ndim))


def param_specs(params, ctx: ShardingCtx | None = None):
    """Pytree of PartitionSpec matching a param pytree (dict-of-dict paths)."""
    c = ctx or _CTX

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, prefix) for v in tree]
            return type(tree)(t)
        shape = tuple(tree.shape)
        return param_spec(prefix, shape, c)

    return walk(params, "")


def compute_spec(path: str, shape: tuple[int, ...],
                 ctx: ShardingCtx | None = None) -> P:
    """The spec a weight should have *at use*: its storage spec with the
    FSDP (data/pod) axes dropped.  Constraining the bf16 cast to this spec
    makes GSPMD all-gather the small weight over dp (ZeRO-3 semantics)
    instead of all-reducing x-sized activations over dp per matmul."""
    c = ctx or _CTX
    spec = param_spec(path, shape, c)
    dset = set(c.data_axes)

    def strip(axis):
        if axis is None:
            return None
        if isinstance(axis, tuple):
            kept = tuple(a for a in axis if a not in dset)
            return kept if kept else None
        return None if axis in dset else axis

    return P(*[strip(a) for a in spec])


def compute_view(params, dtype=None, ctx: ShardingCtx | None = None):
    """Cast >=2-D float weights to the compute dtype and constrain every
    leaf to its dp-free compute spec.  Called once per block application —
    the single place FSDP weight all-gathers are materialized."""
    c = ctx or _CTX

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)([walk(v, prefix) for v in tree])
        w = tree
        if dtype is not None and w.ndim >= 2 and w.dtype == jax.numpy.float32:
            w = w.astype(dtype)
        if c.mesh is None:
            return w
        spec = compute_spec(prefix, tuple(w.shape), c)
        return jax.lax.with_sharding_constraint(w, NamedSharding(c.mesh, spec))

    return walk(params, "")


def named_shardings(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
