from .sharding import (ShardingCtx, act_spec, current_ctx, param_specs,
                       set_sharding_ctx, shard, use_sharding)

__all__ = ["ShardingCtx", "set_sharding_ctx", "use_sharding", "current_ctx",
           "shard", "act_spec", "param_specs"]
