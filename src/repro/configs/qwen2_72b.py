"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = QWEN2_72B = register(ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
))
