"""Aggregator for the ten assigned architectures (one module per arch).

Sources per assignment:
  granite-moe-1b-a400m   [hf:ibm-granite/granite-3.0-1b-a400m-base]
  phi3.5-moe-42b-a6.6b   [hf:microsoft/Phi-3.5-MoE-instruct]
  seamless-m4t-large-v2  [arXiv:2308.11596]
  recurrentgemma-9b      [arXiv:2402.19427]
  qwen2-72b              [arXiv:2407.10671]
  command-r-35b          [hf:CohereForAI/c4ai-command-r-v01]
  granite-8b             [arXiv:2405.04324]
  qwen2.5-32b            [hf:Qwen/Qwen2.5-32B]
  xlstm-1.3b             [arXiv:2405.04517]
  qwen2-vl-7b            [arXiv:2409.12191]
"""

from .granite_moe_1b_a400m import GRANITE_MOE_1B
from .phi35_moe_42b_a6_6b import PHI35_MOE
from .seamless_m4t_large_v2 import SEAMLESS_M4T
from .recurrentgemma_9b import RECURRENTGEMMA_9B
from .qwen2_72b import QWEN2_72B
from .command_r_35b import COMMAND_R_35B
from .granite_8b import GRANITE_8B
from .qwen25_32b import QWEN25_32B
from .xlstm_1_3b import XLSTM_1_3B
from .qwen2_vl_7b import QWEN2_VL_7B

ALL_ARCHS = [
    GRANITE_MOE_1B, PHI35_MOE, SEAMLESS_M4T, RECURRENTGEMMA_9B, QWEN2_72B,
    COMMAND_R_35B, GRANITE_8B, QWEN25_32B, XLSTM_1_3B, QWEN2_VL_7B,
]
