"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = GRANITE_MOE_1B = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab_size=49155, ffn="moe", moe=MoEConfig(n_experts=32, top_k=8),
    tie_embeddings=True, rope_theta=1e4,
))
