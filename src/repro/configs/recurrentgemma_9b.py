"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = RECURRENTGEMMA_9B = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, head_dim=256,
    pattern=("rglru", "rglru", "attn_local"), window=2048,
    rnn_width=4096, tie_embeddings=True, rope_theta=1e4,
))
