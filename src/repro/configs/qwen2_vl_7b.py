"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = QWEN2_VL_7B = register(ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
))
