"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = COMMAND_R_35B = register(ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab_size=256000, rope_theta=8e6, tie_embeddings=True,
))
