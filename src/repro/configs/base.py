"""Model / shape configuration system.

Every assigned architecture is a ``ModelConfig``; every workload shape is a
``ShapeConfig``.  ``registry`` maps ``--arch`` ids to configs; reduced smoke
variants derive from the full config via ``smoke()``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    moe: MoEConfig | None = None
    qkv_bias: bool = False
    norm: str = "rms"                 # rms | ln
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # layer pattern: repeating unit of mixer kinds; padded/truncated to n_layers.
    # kinds: attn | attn_local | rglru | mlstm | slstm
    pattern: tuple[str, ...] = ("attn",)
    window: int = 0                   # local-attention window (attn_local)
    ffn: str = "swiglu"               # swiglu | gelu | relu | moe | none(xlstm)
    # enc-dec (audio)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # vlm
    mrope_sections: tuple[int, int, int] | None = None
    # ssm
    rnn_width: int = 0                # rglru recurrence width (0 -> d_model)
    conv_width: int = 4

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def smoke(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes = dict(
            n_layers=max(2, len(self.pattern)) if len(self.pattern) > 1 else 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=96 if self.d_ff else 0,
            vocab_size=128,
            window=min(self.window, 16) if self.window else 0,
            rnn_width=64 if self.rnn_width else 0,
        )
        if self.moe is not None:
            # drop-free capacity so prefill/decode consistency is exact
            changes["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2),
                                       capacity_factor=8.0)
        if self.enc_dec:
            changes["n_enc_layers"] = 2
        if self.mrope_sections:
            changes["mrope_sections"] = (2, 3, 3)
        return dataclasses.replace(self, **changes)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, dh, h, kvh = self.d_model, self.dh, self.n_heads, self.n_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind in ("attn", "attn_local"):
                total += d * dh * (h + 2 * kvh) + h * dh * d      # qkvo
                if self.qkv_bias:
                    total += dh * (h + 2 * kvh)
            elif kind == "rglru":
                w = self.rnn_width or d
                total += 2 * d * w + w * d + self.conv_width * w + 3 * w
            elif kind == "mlstm":
                up = 2 * d
                total += (2 * d * up                      # up + gate proj
                          + 3 * up * up // self.n_heads   # block-diag qkv
                          + up * 2 * self.n_heads         # i/f gates
                          + up * d)                       # down proj
            elif kind == "slstm":
                dh_s = d // self.n_heads
                total += d * 4 * d + self.n_heads * dh_s * 4 * dh_s + d * d
            # ffn
            if self.ffn == "moe":
                e = self.moe.n_experts
                total += d * e + e * (3 * d * self.d_ff)
            elif self.ffn == "swiglu":
                total += 3 * d * self.d_ff
            elif self.ffn in ("gelu", "relu"):
                total += 2 * d * self.d_ff
            total += 2 * d                                         # norms
        if self.enc_dec:
            # encoder layers: self-attn + ffn; decoder adds cross-attn
            total += self.n_enc_layers * (d * dh * (h + 2 * kvh) + h * dh * d
                                          + 2 * d * self.d_ff + 2 * d)
            total += self.n_layers * (d * dh * (h + 2 * kvh) + h * dh * d)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        dense = dataclasses.replace(self, moe=None, ffn="swiglu")
        per_expert = 3 * self.d_model * self.d_ff
        return (dense.param_count() - self.n_layers * 3 * self.d_model * self.d_ff
                + self.n_layers * (self.moe.top_k * per_expert
                                   + self.d_model * self.moe.n_experts))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs able to run long_500k (sub-quadratic / bounded-state sequence mixing)
SUBQUADRATIC = {"recurrentgemma-9b", "xlstm-1.3b"}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import ALL  # noqa: F401  (ensures arch modules imported)
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    from . import ALL  # noqa: F401
    return dict(_REGISTRY)


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    cells = []
    for arch in all_configs():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in SUBQUADRATIC:
                continue
            cells.append((arch, shape))
    return cells
