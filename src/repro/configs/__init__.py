from .base import (SHAPES, SUBQUADRATIC, ModelConfig, MoEConfig, ShapeConfig,
                   all_configs, get_config, register, runnable_cells)
from . import archs as ALL  # noqa: F401  — populates the registry

__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES", "SUBQUADRATIC",
           "get_config", "all_configs", "register", "runnable_cells"]
