"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = GRANITE_8B = register(ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=49152, rope_theta=1e4,
))
