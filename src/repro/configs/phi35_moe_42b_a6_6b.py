"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = PHI35_MOE = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, ffn="moe", moe=MoEConfig(n_experts=16, top_k=2),
    rope_theta=1e4,
))
