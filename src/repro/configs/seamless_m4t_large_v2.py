"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = SEAMLESS_M4T = register(ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256206, ffn="relu", norm="ln", enc_dec=True, n_enc_layers=24,
    rope_theta=1e4,
))
