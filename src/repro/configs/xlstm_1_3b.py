"""Assigned architecture config — see archs.py docstring for source."""

from .base import ModelConfig, MoEConfig, register

CONFIG = XLSTM_1_3B = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, ffn="none",
    pattern=("mlstm",) * 7 + ("slstm",),   # xLSTM[7:1]
    rope_theta=1e4,
))
