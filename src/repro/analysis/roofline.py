"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step-per-chip:

    compute    = HLO_FLOPs / (chips × 197e12)          [bf16 peak]
    memory     = HLO_bytes / (chips × 819e9)           [HBM]
    collective = collective_bytes / 50e9               [per-chip ICI bytes]

``cost_analysis()`` visits while-loop bodies once, so HLO_FLOPs/bytes come
from the unrolled 1-unit / 2-unit probe extrapolation (dryrun.py), and
collective bytes come from parsing the optimized per-device HLO with
while-body trip-count multipliers (``known_trip_count``).

Per-op per-chip traffic model (ring schedules on the torus, g = group size):
    all-gather       out_bytes × (g-1)/g
    reduce-scatter   in_bytes  × (g-1)/g
    all-reduce       in_bytes  × 2(g-1)/g
    all-to-all       in_bytes  × (g-1)/g
    collective-permute  in_bytes
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

HW = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"?known_trip_count"?[:=]\{"?n"?[:=]"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:to_apply|calls|condition|body)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    per_chip_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    op_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))


def parse_hlo(hlo_text: str, total_devices: int) -> CollectiveStats:
    """Per-chip collective bytes for one execution of the compiled module."""
    # 1) split into computations
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            comps[cur].append(line)

    # 2) call-graph multipliers (while bodies x trip count)
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] += m
        for line in comps[name]:
            trip = 1.0
            tm = _TRIP_RE.search(line)
            wm = _WHILE_RE.search(line)
            if wm:
                if tm:
                    trip = float(tm.group(1))
                visit(wm.group(1), m * trip)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if cm:
                    visit(cm.group(1), m * trip)
                continue
            for callee in _CALL_RE.findall(line):
                visit(callee, m)
            bm = _BRANCH_RE.search(line)
            if bm:
                for callee in bm.group(1).split(","):
                    visit(callee.strip().lstrip("%"), m)

    if entry is None:
        entry = next(iter(comps))
    visit(entry, 1.0)

    # 3) collective bytes
    stats = CollectiveStats()
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            result_text, kind = om.group(1), om.group(2)
            operand_text = line[om.end():]
            out_b = _shape_bytes(result_text)
            in_b = _shape_bytes(operand_text.split(")", 1)[0] + ")")
            if in_b == 0:
                in_b = out_b
            g = _group_size(line, total_devices)
            frac = (g - 1) / g if g > 1 else 0.0
            if kind == "all-gather":
                chip = out_b * frac
            elif kind == "reduce-scatter":
                chip = in_b * frac
            elif kind == "all-reduce":
                chip = 2 * in_b * frac
            elif kind == "all-to-all":
                chip = in_b * frac
            else:                                   # collective-permute
                chip = in_b
            stats.per_chip_bytes += m * chip
            stats.by_kind[kind] += m * chip
            stats.op_counts[kind] += int(m)
    return stats


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> dict:
    t_c = flops_per_chip / HW["peak_flops"]
    t_m = bytes_per_chip / HW["hbm_bw"]
    t_x = coll_bytes_per_chip / HW["ici_bw"]
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bound": dom, "step_s_lower_bound": max(t_c, t_m, t_x)}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference fwd), N = active params.

    D counted as processed tokens per step (decode: one token per sequence).
    Enc-dec: encoder params see src frames (seq/8 — the stub frontend's
    frame rate), decoder params see target tokens; decode touches only the
    decoder."""
    k = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        toks = float(shape.global_batch)
    else:
        toks = float(shape.global_batch * shape.seq_len)
    n = cfg.active_param_count()
    if not cfg.enc_dec:
        return k * n * toks
    d, dh, h, kvh = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    enc_layer = d * dh * (h + 2 * kvh) + h * dh * d + 2 * d * cfg.d_ff + 2 * d
    n_enc = cfg.n_enc_layers * enc_layer
    n_dec = n - n_enc
    src_toks = float(shape.global_batch * max(shape.seq_len // 8, 16))
    if shape.kind == "decode":
        return k * n_dec * toks
    return k * (n_enc * src_toks + n_dec * toks)
