"""Sharded, atomic, mesh-shape-agnostic checkpointing.

Layout: <dir>/step_<N>/ holding one .npy per pytree leaf (path-encoded
filename) + manifest.json.  Commit protocol: write into step_<N>.tmp, fsync,
``os.replace`` to step_<N> — a crash mid-write never corrupts the latest
complete checkpoint.  Restore rebuilds leaves and ``device_put``s them with
the *current* shardings, so restarts may change mesh shape (elastic
re-mesh) or process count.

A background thread performs the host-side write so the train loop only
blocks on ``device_get`` (async checkpointing).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _leaf_name(path) -> str:
    return _SAFE.sub("~", jax.tree_util.keystr(path))


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         async_: bool = False) -> threading.Thread | None:
    """Checkpoint ``tree`` (+ JSON-serializable ``extra``) at ``step``."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    host = [(_leaf_name(p), np.asarray(jax.device_get(x))) for p, x in leaves]

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        for name, arr in host:
            np.save(os.path.join(tmp, name + ".npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": [n for n, _ in host],
                       "extra": extra or {}}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None) -> tuple:
    """Restore a pytree shaped ``like``; returns (tree, extra)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_like[0]:
        arr = np.load(os.path.join(d, _leaf_name(path) + ".npy"))
        assert arr.shape == tuple(leaf.shape), f"{path}: {arr.shape} != {leaf.shape}"
        out.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(leaves_like[1], out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest["extra"]


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted([d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                    and not d.endswith(".tmp")])
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
