"""Deterministic synthetic LM data pipeline with checkpointable state.

Real deployments swap ``SyntheticTokens`` for a tokenized corpus reader; the
interface (stateful iterator + ``state()``/``restore()`` for checkpoint
inclusion, per-host sharding by process index) is what the trainer depends
on.  Tokens are a position/step hash, so any restored pipeline reproduces
the exact stream — fault-tolerant restarts see identical data.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0
    # per-host sharding (single-host containers: 1 of 1)
    process_index: int = 0
    process_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.process_count == 0
        return self.global_batch // self.process_count

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, self.process_index, self.step]))
        toks = rng.integers(0, self.vocab_size,
                            (self.host_batch, self.seq_len), dtype=np.int32)
        # inject learnable structure: token t+1 correlates with token t
        toks[:, 1::2] = (toks[:, 0::2] * 31 + 7) % self.vocab_size
        self.step += 1
        return {"tokens": toks}

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])


def make_pipeline(cfg, shape, seed: int = 0,
                  process_index: int = 0, process_count: int = 1):
    return SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                           global_batch=shape.global_batch, seed=seed,
                           process_index=process_index,
                           process_count=process_count)
