"""The jit-able training step: microbatched gradient accumulation (scan),
remat+pattern-scan forward, AdamW update.

Gradient synchronization: with FSDP/DP shardings, GSPMD inserts the
reduce-scatter/all-reduce schedule — on a torus this is the paper's §8
super-connectivity (log-depth) realization of the §7.4 two-phase sum.  The
R7-faithful ring schedule is available in ``repro.cpm.collectives`` and is
compared in the benchmarks; the compiled collective bytes are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from . import optimizer as opt


def make_train_step(cfg: ModelConfig, opt_cfg: opt.OptConfig,
                    num_microbatches: int = 1, remat: bool = True,
                    loss_chunk: int = 1024):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return lm.loss_fn(params, cfg, batch, remat=remat, loss_chunk=loss_chunk)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            k = num_microbatches

            def split(x, axis=0):
                b = x.shape[axis]
                assert b % k == 0, f"batch {b} % microbatches {k}"
                if axis == 0:
                    return x.reshape(k, b // k, *x.shape[1:])
                # batch axis not leading (e.g. pos_ids (3, B, S)): split axis 1
                out = x.reshape(*x.shape[:axis], k, b // k, *x.shape[axis + 1:])
                return jnp.moveaxis(out, axis, 0)

            mbs = {kk: split(v, 1 if kk == "pos_ids" else 0)
                   for kk, v in batch.items()}
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                gacc, lacc = carry
                (l, m), g = grad_fn(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), m

            (grads, loss_sum), ms = jax.lax.scan(body, (zero_g, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)

        params, opt_state, om = opt.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, loss_chunk: int = 1024):
    def eval_step(params, batch):
        loss, metrics = lm.loss_fn(params, cfg, batch, remat=False,
                                   loss_chunk=loss_chunk)
        return dict(metrics, loss=loss)
    return eval_step
