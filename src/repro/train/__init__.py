from . import checkpoint, data, fault_tolerance, optimizer, train_step
from .optimizer import OptConfig, apply_updates, init_opt_state
from .train_step import make_eval_step, make_train_step

__all__ = ["optimizer", "train_step", "data", "checkpoint", "fault_tolerance",
           "OptConfig", "init_opt_state", "apply_updates", "make_train_step",
           "make_eval_step"]
