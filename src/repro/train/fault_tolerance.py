"""Fault-tolerant training loop: checkpoint/restart, heartbeat-based
straggler detection, elastic re-mesh on restore.

At 1000+ node scale the failure model is: (a) a host dies mid-step (SIGKILL
— survived via the atomic checkpoint protocol in ``checkpoint.py``); (b) a
host stalls (straggler — detected by the per-step heartbeat deadline, the
runbook response is to restart onto the spare pool and restore); (c) the job
is re-scheduled onto a different topology (elastic — checkpoints are
mesh-shape-agnostic full arrays, so restore under any mesh re-shards via
``device_put``).  On real pods the heartbeat/restart loop is driven by the
cluster coordinator (GKE/Borg health checks + jax.distributed); this module
implements the per-process logic and is exercised end-to-end (kill/restore)
by tests/test_train.py.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax

from . import checkpoint

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    step_deadline_s: float = 0.0       # 0 = no straggler deadline (CPU tests)
    max_restarts: int = 3


class Heartbeat:
    """Per-step liveness record.  A monitor (cluster-side) restarts ranks
    whose heartbeat age exceeds the deadline; here we expose the same signal
    locally so the loop can flag straggling steps."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self.last = time.monotonic()
        self.straggler_steps: list[int] = []

    def beat(self, step: int) -> bool:
        now = time.monotonic()
        late = self.deadline_s > 0 and (now - self.last) > self.deadline_s
        if late:
            self.straggler_steps.append(step)
            log.warning("straggler: step %d took %.1fs (deadline %.1fs)",
                        step, now - self.last, self.deadline_s)
        self.last = now
        return late


def resume_or_init(fcfg: FaultConfig, init_fn, like=None, shardings=None):
    """Restore the latest complete checkpoint or initialize fresh.

    Returns (state_tree, extra, start_step).  ``init_fn()`` must build the
    fresh state; ``like`` (defaults to the fresh state) provides the
    restore skeleton so the checkpoint can have been written under a
    different mesh.
    """
    step = checkpoint.latest_step(fcfg.ckpt_dir)
    if step is None:
        state = init_fn()
        return state, {}, 0
    like = like if like is not None else jax.eval_shape(init_fn)
    state, extra = checkpoint.restore(fcfg.ckpt_dir, step, like, shardings)
    log.info("restored checkpoint step %d from %s", step, fcfg.ckpt_dir)
    return state, extra, step


def run_loop(fcfg: FaultConfig, state, step_fn, data_iter, start_step: int,
             num_steps: int, on_metrics=None):
    """Drive ``num_steps`` of ``step_fn(state, batch) -> (state, metrics)``
    with periodic async checkpointing + heartbeat."""
    hb = Heartbeat(fcfg.step_deadline_s)
    pending = None
    for step in range(start_step, num_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        hb.beat(step)
        if on_metrics is not None:
            on_metrics(step, metrics)
        if fcfg.ckpt_every and (step + 1) % fcfg.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = checkpoint.save(
                fcfg.ckpt_dir, step + 1, state,
                extra={"data": data_iter.state()}, async_=True)
            checkpoint.gc_old(fcfg.ckpt_dir, fcfg.keep)
    if pending is not None:
        pending.join()
    return state, hb
