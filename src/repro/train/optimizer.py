"""AdamW with fully-sharded (ZeRO-3) states, cosine schedule, global-norm
clipping.  Pure pytree-in/pytree-out — optimizer states inherit the param
PartitionSpecs, so FSDP sharding of m/v costs one tree_map."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY = ("scale", "bias", "a_param", "w_input_gate", "norm")


def _decay_mask(path: str) -> bool:
    return not any(t in path for t in _NO_DECAY)


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        pstr = jax.tree_util.keystr(path)
        if cfg.weight_decay and _decay_mask(pstr):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    params = jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef")
                                          else treedef, new_p)
    state = {"mu": jax.tree_util.tree_unflatten(jax.tree.structure(grads), new_mu),
             "nu": jax.tree_util.tree_unflatten(jax.tree.structure(grads), new_nu),
             "step": step}
    return params, state, {"lr": lr, "grad_norm": gnorm}
